"""Multi-process cluster drill: scaling sweep + kill-a-worker under load.

Two sections, mirroring bench_faults' accounting discipline:

  * SCALING — aggregate router QPS over a fixed corpus sharded 1/2/4
    ways, one supervised worker process per shard.  The >=1.5x-at-4-
    workers gate is enforced ONLY when >=4 CPUs are visible: the whole
    point of the process tier is escaping the GIL, which requires cores
    to escape to.  On smaller boxes the sweep still runs and the gate is
    recorded as skipped with the reason — never silently passed.

  * KILL DRILL — SIGKILL one worker mid-traffic (`core.faults.
    ProcessKiller` armed on the live pid) and assert the failure
    contract end to end: every request RESOLVES (completed full, clean
    partial, or clean rejection — zero hangs, buckets sum exactly to
    requests issued), every completed answer — full OR partial — is
    bit-identical to a single-process reference merged over exactly the
    shards that answered, and the supervisor's respawn restores full
    bit-identical coverage.

References come from `make_host_search_dist_fn` per shard folded by the
same `core.shard_math.merge_topk` the router uses, so "bit-identical"
is exact array equality, not a recall bound.

    PYTHONPATH=src:. python benchmarks/bench_cluster.py          # full
    PYTHONPATH=src:. python benchmarks/bench_cluster.py --quick  # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import tempfile
import threading
import time

import numpy as np

from repro.core.faults import ProcessKiller
from repro.core.shard_math import merge_topk
from repro.serving.cluster import ShardCluster
from repro.serving.router import (DegradedServiceError, ShardRouter,
                                  SocketShardClient)

SCHEMA_VERSION = 1
K, L, W = 10, 32, 4
TOTAL = 8000                 # full-mode corpus prefix, sharded 1/2/4 ways
WORKER_COUNTS = (1, 2, 4)
SWEEP_SECONDS = 4.0
SWEEP_THREADS = 4
DRILL_SHARDS = 4
DRILL_REQUESTS = 240
DRILL_THREADS = 4
KILL_AT = 60                 # request tick that fires the SIGKILL
SHARD_DEADLINE_S = 3.0
HANG_BOUND_S = 12.0          # 2x(deadline+connect) + generous slack


def cpu_count() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


# ---------------------------------------------------------------------------
# single-process references
# ---------------------------------------------------------------------------


def per_shard_refs(shards, queries, *, k, L, w):
    """(n_shards, nq, k) ids + dists from the same search the workers
    run, computed in THIS process — the bit-identity bar."""
    from repro.core.index_io import HostIndex
    from repro.serving.engine import make_host_search_dist_fn
    ids, dists = [], []
    for corpora in shards:
        idx = HostIndex.load(corpora["default"], cache_bytes=8 << 20)
        i, d = make_host_search_dist_fn(idx, L=L, w=w)(queries, k)
        ids.append(np.asarray(i))
        dists.append(np.asarray(d))
        idx.close()
    return ids, dists


def merged_ref(ref_ids, ref_dists, shard_set, qi, k):
    """Reference answer for query `qi` over exactly `shard_set`."""
    return merge_topk([ref_ids[s][qi] for s in shard_set],
                      [ref_dists[s][qi] for s in shard_set], k)


# ---------------------------------------------------------------------------
# cluster + router plumbing
# ---------------------------------------------------------------------------


def start_cluster(shards, socket_dir, *, k_unused=None, L=L, w=W,
                  cache_bytes=8 << 20, **kw):
    cluster = ShardCluster(shards, socket_dir=socket_dir, L=L, w=w,
                           cache_bytes=cache_bytes, **kw)
    cluster.start()
    eps = cluster.endpoints()
    assert all(eps), f"cluster started with down shards: {eps}"
    router = ShardRouter([SocketShardClient(p) for p in eps],
                         min_shards=1, shard_deadline_s=SHARD_DEADLINE_S,
                         endpoints_fn=cluster.endpoints)
    return cluster, router


# ---------------------------------------------------------------------------
# scaling sweep
# ---------------------------------------------------------------------------


def bench_scaling(queries, *, k, total, worker_counts=WORKER_COUNTS,
                  duration_s=SWEEP_SECONDS, n_threads=SWEEP_THREADS) -> dict:
    """Aggregate QPS through the router at each worker count."""
    from benchmarks import common as C
    rows = {}
    for n in worker_counts:
        shards, _ = C.ensure_shard_indices(n, total=total)
        with tempfile.TemporaryDirectory(prefix="clus-sweep") as sd:
            cluster, router = start_cluster(shards, sd)
            try:
                for qi in range(min(8, len(queries))):      # warm caches
                    router.search(queries[qi], k)
                stop_at = time.monotonic() + duration_s
                counts = [0] * n_threads
                errors = [0] * n_threads

                def pump(t):
                    i = t
                    while time.monotonic() < stop_at:
                        try:
                            r = router.search(queries[i % len(queries)], k)
                            if not r.partial:
                                counts[t] += 1
                            else:
                                errors[t] += 1
                        except (DegradedServiceError, Exception):
                            errors[t] += 1
                        i += n_threads

                t0 = time.perf_counter()
                threads = [threading.Thread(target=pump, args=(t,))
                           for t in range(n_threads)]
                for th in threads:
                    th.start()
                for th in threads:
                    th.join()
                wall = time.perf_counter() - t0
                rows[n] = dict(qps=sum(counts) / wall,
                               completed=int(sum(counts)),
                               degraded=int(sum(errors)), wall_s=wall)
                print(f"[bench_cluster] {n} worker(s): "
                      f"{rows[n]['qps']:.0f} qps "
                      f"({rows[n]['completed']} full answers)")
            finally:
                router.close()
                cluster.stop()
    cpus = cpu_count()
    out = dict(worker_counts=list(worker_counts), rows=rows, cpus=cpus)
    if cpus >= 4 and 1 in rows and 4 in rows:
        ratio = rows[4]["qps"] / rows[1]["qps"]
        out["gate"] = dict(enforced=True, ratio=ratio,
                           passed=bool(ratio >= 1.5))
    else:
        out["gate"] = dict(
            enforced=False, passed=None,
            reason=f"{cpus} CPU(s) visible; the 1.5x-at-4-workers gate "
                   "needs >= 4 cores to be meaningful")
    return out


# ---------------------------------------------------------------------------
# kill-a-worker drill
# ---------------------------------------------------------------------------


def run_kill_drill(shards, queries, *, k, L, w, n_requests, n_threads,
                   kill_at, victim_shard, cache_bytes=8 << 20,
                   respawn_queries=16, respawn_timeout_s=30.0) -> dict:
    """SIGKILL `victim_shard` at the `kill_at`-th request; account for
    every request; bit-check every completed answer against references
    merged over exactly the shards that answered it.  Returns the full
    accounting dict; raises nothing — callers assert via
    `drill_failures` so full and quick share one body."""
    ref_ids, ref_dists = per_shard_refs(shards, queries, k=k, L=L, w=w)
    all_shards = range(len(shards))
    with tempfile.TemporaryDirectory(prefix="clus-drill") as sd:
        cluster, router = start_cluster(
            shards, sd, L=L, w=w, cache_bytes=cache_bytes,
            heartbeat_s=0.1, backoff_s=0.05, stable_s=2.0)
        killer = ProcessKiller(at=kill_at)
        killer.arm(lambda: cluster.pid(victim_shard))
        records = []
        rec_lock = threading.Lock()

        def pump(t):
            for j in range(t, n_requests, n_threads):
                killer.tick()
                qi = j % len(queries)
                t0 = time.perf_counter()
                try:
                    r = router.search(queries[qi], k)
                    rec = dict(qi=qi, outcome=("partial" if r.partial
                                               else "full"),
                               ids=r.ids, dists=r.dists,
                               failed=list(r.failed_shards))
                except DegradedServiceError:
                    rec = dict(qi=qi, outcome="rejected")
                except Exception as e:   # noqa: BLE001 — accounting drill
                    rec = dict(qi=qi, outcome="other_error",
                               err=f"{type(e).__name__}: {e}")
                rec["latency_s"] = time.perf_counter() - t0
                with rec_lock:
                    records.append(rec)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=pump, args=(t,))
                   for t in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        stream_wall = time.perf_counter() - t0

        # --- verification pass: every completed answer vs the reference
        # merged over exactly the shards that answered it
        buckets = dict(full=0, partial=0, rejected=0, other_error=0)
        mismatches, hung = 0, 0
        max_latency = 0.0
        for rec in records:
            buckets[rec["outcome"]] += 1
            max_latency = max(max_latency, rec["latency_s"])
            if rec["latency_s"] > HANG_BOUND_S:
                hung += 1
            if rec["outcome"] in ("full", "partial"):
                answered = [s for s in all_shards
                            if s not in rec.get("failed", [])]
                eids, edists = merged_ref(ref_ids, ref_dists, answered,
                                          rec["qi"], k)
                if not (np.array_equal(rec["ids"], eids)
                        and np.array_equal(rec["dists"], edists)):
                    mismatches += 1

        # --- respawn: supervisor must restore full bit-identical coverage
        recovered = cluster.wait_healthy(respawn_timeout_s)
        respawn = dict(all_full=True, mismatches=0, n=respawn_queries)
        if recovered:
            for j in range(respawn_queries):
                qi = j % len(queries)
                try:
                    r = router.search(queries[qi], k)
                except (DegradedServiceError, Exception):
                    respawn["all_full"] = False
                    continue
                if r.partial:
                    respawn["all_full"] = False
                    continue
                eids, edists = merged_ref(ref_ids, ref_dists, all_shards,
                                          qi, k)
                if not (np.array_equal(r.ids, eids)
                        and np.array_equal(r.dists, edists)):
                    respawn["mismatches"] += 1
        cstats = cluster.stats()
        rstats = router.stats()
        router.close()
        cluster.stop()
    return dict(
        n_requests=n_requests,
        n_threads=n_threads,
        stream_wall_s=stream_wall,
        victim_shard=victim_shard,
        killed_pid=killer.killed_pid,
        buckets=buckets,
        accounted=int(sum(buckets.values())),
        hung=hung,
        max_latency_s=max_latency,
        mismatches=mismatches,
        bit_identical=mismatches == 0,
        recovered=recovered,
        respawn=respawn,
        restarts=cstats["shards"][victim_shard]["restarts"],
        quarantined=cstats["quarantined"],
        router=rstats,
        events=[e["what"] for e in cstats["events"]],
    )


def drill_failures(d: dict) -> list:
    """The drill's pass/fail contract, shared by full and quick modes."""
    fails = []
    if d["killed_pid"] is None:
        fails.append("ProcessKiller never fired — the drill killed nothing")
    if d["accounted"] != d["n_requests"]:
        fails.append(f"accounting leak: {d['accounted']} bucketed vs "
                     f"{d['n_requests']} requests issued")
    if d["hung"]:
        fails.append(f"{d['hung']} request(s) exceeded the "
                     f"{HANG_BOUND_S}s hang bound "
                     f"(max {d['max_latency_s']:.1f}s)")
    if d["buckets"]["other_error"]:
        fails.append(f"unclean outcomes: {d['buckets']}")
    if not d["bit_identical"]:
        fails.append(f"{d['mismatches']} completed answer(s) differ from "
                     "single-process references over the answering shards")
    if d["router"]["shard_failures"] < 1:
        fails.append("router never observed a shard failure — the kill "
                     "landed outside traffic, drill proves nothing")
    if not d["recovered"]:
        fails.append("cluster never returned to healthy after the kill")
    if d["restarts"] < 1:
        fails.append("supervisor recorded no respawn of the victim")
    if not d["respawn"]["all_full"] or d["respawn"]["mismatches"]:
        fails.append(f"post-respawn coverage not restored: {d['respawn']}")
    return fails


# ---------------------------------------------------------------------------
# full mode
# ---------------------------------------------------------------------------


def all_benchmarks():
    from benchmarks import common as C
    rows = []
    report = {"schema_version": SCHEMA_VERSION,
              "workload": dict(total=TOTAL, k=K, L=L, w=W,
                               worker_counts=list(WORKER_COUNTS),
                               drill_shards=DRILL_SHARDS,
                               drill_requests=DRILL_REQUESTS,
                               kill_at=KILL_AT)}
    _, queries, _ = C.corpus()

    report["scaling"] = sc = bench_scaling(queries, k=K, total=TOTAL)
    for n, r in sc["rows"].items():
        rows.append((f"cluster_qps_{n}w", r["qps"],
                     f"completed={r['completed']}"))

    shards, _ = C.ensure_shard_indices(DRILL_SHARDS, total=TOTAL)
    report["drill"] = d = run_kill_drill(
        shards, queries, k=K, L=L, w=W, n_requests=DRILL_REQUESTS,
        n_threads=DRILL_THREADS, kill_at=KILL_AT,
        victim_shard=DRILL_SHARDS // 2)
    fails = drill_failures(d)
    if sc["gate"]["enforced"] and not sc["gate"]["passed"]:
        fails.append(f"scaling gate: {sc['gate']['ratio']:.2f}x at 4 "
                     "workers < 1.5x")
    report["drill"]["failures"] = fails
    b = d["buckets"]
    rows.append(("cluster_drill_accounted",
                 d["accounted"] / d["n_requests"],
                 f"full={b['full']}_partial={b['partial']}_"
                 f"rejected={b['rejected']}"))
    rows.append(("cluster_bit_identical", float(d["bit_identical"]),
                 f"restarts={d['restarts']}_hung={d['hung']}"))
    report["headline"] = dict(
        drill_passed=not fails,
        killed_pid=d["killed_pid"],
        buckets=b,
        hung=d["hung"],
        bit_identical=d["bit_identical"],
        recovered=d["recovered"],
        restarts=d["restarts"],
        scaling_gate=sc["gate"],
        qps={str(n): r["qps"] for n, r in sc["rows"].items()})
    report["provenance"] = C.provenance("cluster")
    dest = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_cluster.json")
    with open(os.path.abspath(dest), "w") as f:
        json.dump(report, f, indent=1, default=str)
    print(f"[bench_cluster] wrote {os.path.abspath(dest)}")
    if fails:
        for msg in fails:
            print(f"[bench_cluster] FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    return rows


# ---------------------------------------------------------------------------
# CI smoke
# ---------------------------------------------------------------------------


def _tiny_shards(td: str, *, n_shards=2, per_shard=700, dim=32, m=8):
    """Throwaway global-label shard indices in a tempdir (CI has no
    artifact cache): one shared codebook, contiguous split, global ids
    baked in via write_index(labels=...)."""
    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.shard_math import contiguous_shards
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries
    base = make_clustered(n_shards * per_shard, dim, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=m, iters=6)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    asn = contiguous_shards(len(base), n_shards)
    shards = []
    for s in range(n_shards):
        lo, hi = asn.bounds(s)
        g = build_vamana(base[lo:hi], R=12, L=24, seed=s)
        p = os.path.join(td, f"shard{s}")
        write_index(p, vectors=base[lo:hi], graph=g, centroids=cents,
                    codes=codes[lo:hi], metric="l2", mode="aisaq",
                    labels=np.arange(lo, hi, dtype=np.int64))
        shards.append({"default": p})
    return shards, make_queries(16, base, seed=9)


def quick_smoke() -> int:
    """CI smoke: the identical kill drill on 2 tiny tempdir shards.
    Asserts the full failure contract — kill fired, zero hangs, exact
    bucket accounting, bit-identity of every completed answer, respawn
    restores full coverage.  The scaling sweep is skipped (CI boxes
    rarely have the cores to make it meaningful)."""
    t0 = time.perf_counter()
    with tempfile.TemporaryDirectory(prefix="clus-quick") as td:
        shards, queries = _tiny_shards(td)
        drill = run_kill_drill(shards, queries, k=5, L=24, w=W,
                               n_requests=80, n_threads=2, kill_at=25,
                               victim_shard=1, cache_bytes=4 << 20,
                               respawn_queries=8)
        fails = drill_failures(drill)
    wall = time.perf_counter() - t0
    if fails:
        for msg in fails:
            print(f"[bench_cluster --quick] FAIL: {msg}", file=sys.stderr)
        return 1
    b = drill["buckets"]
    print(f"[bench_cluster --quick] kill drill green ({wall:.1f}s): "
          f"full={b['full']} partial={b['partial']} "
          f"rejected={b['rejected']} hung={drill['hung']} "
          f"bit_identical={drill['bit_identical']} "
          f"restarts={drill['restarts']} "
          f"retries={drill['router']['retries']}")
    return 0


if __name__ == "__main__":
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.3f},{extra}")
