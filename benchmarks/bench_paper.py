"""Paper-table benchmarks (Tables 2-5, Figs 3-4, Fig 6 cost model).

Each function returns a list of CSV rows (name, us_per_call, derived).
Scaled to N=20k on this CPU container; same code paths as billion-scale
(DESIGN.md §7 scale note).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.core.index_io import HostIndex, recall_at
from repro.core.index_switch import IndexManager


def _search_stats(idx, q, gt, L, k=10):
    ids, stats = idx.search_batch(q, k, L=L)
    lat = np.mean([s.latency_s for s in stats])
    return (recall_at(ids, gt, 1), recall_at(ids, gt, 10), lat,
            np.mean([s.ios for s in stats]),
            np.mean([s.bytes_read for s in stats]))


def table2_memory():
    """Table 2: resident memory, DiskANN vs AiSAQ (same index family)."""
    paths = C.ensure_indices()
    rows = []
    res = {}
    for mode in ("diskann", "aisaq"):
        idx = HostIndex.load(paths[(mode, C.DEFAULT_M)])
        res[mode] = idx.resident_bytes()
        rows.append((f"table2_resident_{mode}", res[mode] / 1e3,
                     f"KB_mode={mode}"))
        idx.close()
    rows.append(("table2_ratio", res["diskann"] / res["aisaq"],
                 "diskann_over_aisaq"))
    return rows


def table3_load_time():
    paths = C.ensure_indices()
    rows = []
    for mode in ("diskann", "aisaq"):
        ts = []
        for _ in range(5):
            idx = HostIndex.load(paths[(mode, C.DEFAULT_M)])
            ts.append(idx.load_time_s)
            idx.close()
        rows.append((f"table3_load_{mode}", np.median(ts) * 1e6,
                     f"ms={np.median(ts)*1e3:.2f}"))
    return rows


def table4_switch_time():
    paths = C.ensure_subcorpora()
    rows = []
    # with centroid reloading
    mgr = IndexManager(paths)
    mgr.switch("sub0", share_centroids=False)
    ts = [mgr.switch(f"sub{i}", share_centroids=False) for i in (1, 2, 3, 4)]
    rows.append(("table4_switch_reload", np.median(ts) * 1e6,
                 f"ms={np.median(ts)*1e3:.3f}"))
    mgr.close()
    # shared centroids (paper: only ~4KB metadata moves)
    mgr = IndexManager(paths)
    mgr.switch("sub0")
    ts = [mgr.switch(f"sub{i}") for i in (1, 2, 3, 4)]
    rows.append(("table4_switch_shared", np.median(ts) * 1e6,
                 f"ms={np.median(ts)*1e3:.3f}"))
    mgr.close()
    # diskann-mode switch for contrast
    dp = C.ensure_indices()
    mgr = IndexManager({"a": dp[("diskann", C.DEFAULT_M)],
                        "b": dp[("aisaq", C.DEFAULT_M)]})
    mgr.switch("b")
    t = mgr.switch("a", share_centroids=False)
    rows.append(("table4_switch_diskann", t * 1e6, f"ms={t*1e3:.3f}"))
    mgr.close()
    return rows


def fig3_recall_latency():
    base, q, gt = C.corpus()
    paths = C.ensure_indices()
    rows = []
    for mode in ("diskann", "aisaq"):
        idx = HostIndex.load(paths[(mode, C.DEFAULT_M)])
        for L in (10, 20, 40, 80):
            r1, r10, lat, ios, rb = _search_stats(idx, q, gt, L)
            rows.append((f"fig3_{mode}_L{L}", lat * 1e6,
                         f"recall1={r1:.3f}_recall10={r10:.3f}_ios={ios:.0f}"))
        idx.close()
    return rows


def fig4_memory_latency():
    """Fig 4: latency@recall>=0.95 vs resident memory across b_pq."""
    base, q, gt = C.corpus()
    paths = C.ensure_indices(ms=C.PQ_MS)
    rows = []
    for mode in ("diskann", "aisaq"):
        for m in C.PQ_MS:
            idx = HostIndex.load(paths[(mode, m)])
            best = None
            for L in (10, 20, 40, 80, 120):
                r1, _, lat, _, _ = _search_stats(idx, q, gt, L)
                if r1 >= 0.95:
                    best = (lat, L, r1)
                    break
            if best is None:
                best = (lat, L, r1)
            rows.append((f"fig4_{mode}_m{m}", best[0] * 1e6,
                         f"residentKB={idx.resident_bytes()/1e3:.0f}"
                         f"_L={best[1]}_recall1={best[2]:.3f}"))
            idx.close()
    return rows


def table5_multiserver(n_servers: int = 6):
    """Table 5: n search servers over one corpus; Fig 6 cost model."""
    paths = C.ensure_indices()
    rows = []
    for mode in ("diskann", "aisaq"):
        idxs, loads = [], []
        for s in range(n_servers):
            idx = HostIndex.load(paths[(mode, C.DEFAULT_M)])
            loads.append(idx.load_time_s)
            idxs.append(idx)
        total_res = sum(i.resident_bytes() for i in idxs)
        rows.append((f"table5_total_resident_{mode}", total_res / 1e3,
                     f"KB_servers={n_servers}"))
        rows.append((f"table5_avg_load_{mode}", np.mean(loads) * 1e6,
                     f"ms={np.mean(loads)*1e3:.2f}"))
        for i in idxs:
            i.close()
    # Fig 6 cost model at SIFT1B scale (paper constants):
    # DRAM $1.8/GB, SSD $0.054/GB; R=52, b_pq=32, N=1e9
    dram, ssd = 1.8, 0.054
    N, bpq, Rdeg, bfull, bnum = 1e9, 32, 52, 128, 4
    disk_ssd_gb = N * (bfull + bnum * (Rdeg + 1)) / 1e9
    ais_ssd_gb = N * (bfull + bnum * (Rdeg + 1) + Rdeg * bpq) / 1e9
    for n in (1, 2, 4, 6):
        cost_d = n * (N * bpq / 1e9) * dram + disk_ssd_gb * ssd
        cost_a = 0.011 * n * dram + ais_ssd_gb * ssd
        rows.append((f"fig6_cost_n{n}", cost_a, f"aisaq${cost_a:.0f}_"
                     f"diskann${cost_d:.0f}_crossover={cost_a < cost_d}"))
    return rows


def all_benchmarks():
    rows = []
    for fn in (table2_memory, table3_load_time, table4_switch_time,
               fig3_recall_latency, fig4_memory_latency, table5_multiserver):
        t0 = time.time()
        rows += fn()
        print(f"[bench] {fn.__name__} done in {time.time()-t0:.0f}s",
              flush=True)
    return rows
