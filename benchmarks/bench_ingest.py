"""Crash-safe streaming-ingest drill: the write-path acceptance benchmark.

Three phases over a dynamic (aisaq-mode) index:

  1. CONCURRENT INGEST — one writer streams inserts into a live index
     while reader threads search it: sustained insert QPS and search QPS,
     zero reader errors, zero CRC mismatches, every post-ingest result
     consistent (no dangling edges, all inserted vectors findable).
  2. COMPACTION SWAP — a `RetrievalService` keeps serving corpus v1 while
     a sibling copy ingests + deletes, compacts into v2 (tombstone
     reclaim + relabel, atomic publish), and `service.swap` switches the
     pool zero-downtime: every concurrent request completes (0 dropped),
     and recall is measured before and after the swap.
  3. KILL-AT-EVERY-OFFSET — a seeded `KillSwitch` crashes a scripted
     insert/delete/flush workload at EVERY durability-relevant write step
     (journal frame halves, chunk-write halves, data sync, each flush
     stage).  After every single crash, recovery must land on a CRC-clean
     index with no dangling edges whose search results are BIT-IDENTICAL
     to the matching pre-/post-op oracle snapshot — 100% recovery.

    PYTHONPATH=src:. python benchmarks/bench_ingest.py          # full
    PYTHONPATH=src:. python benchmarks/bench_ingest.py --quick  # CI smoke
"""
from __future__ import annotations

import json
import os
import shutil
import sys
import tempfile
import threading
import time

import numpy as np

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.dynamic import DynamicHostIndex
from repro.core.faults import CrashPoint, KillSwitch
from repro.core.index_io import recall_at
from repro.data.vectors import make_clustered, make_queries
from repro.serving.pool import WarmIndexPool
from repro.serving.service import RetrievalService

SCHEMA_VERSION = 1

# full-mode workload sizes (quick shrinks everything)
FULL = dict(n0=2000, dim=32, R=16, pq_m=8, build_L=32, n_insert=150,
            n_readers=3, n_queries=24, drill_n0=400, drill_inserts=4,
            drill_deletes=2, swap_inserts=60, swap_deletes=8,
            swap_clients=4)
QUICK = dict(n0=300, dim=16, R=8, pq_m=8, build_L=24, n_insert=40,
             n_readers=2, n_queries=8, drill_n0=200, drill_inserts=2,
             drill_deletes=1, swap_inserts=16, swap_deletes=3,
             swap_clients=2)
K, L, W = 5, 32, 4


def _build(path: str, base: np.ndarray, p: dict, n: int, seed: int = 0):
    cfg = IndexConfig(name="ingest", n_vectors=n, dim=p["dim"], R=p["R"],
                      pq_m=p["pq_m"], build_L=p["build_L"])
    build_index(path, base[:n], cfg, mode="aisaq", seed=seed)


# ---------------------------------------------------------------------------
# phase 1: concurrent ingest
# ---------------------------------------------------------------------------


def bench_concurrent_ingest(td: str, base: np.ndarray, p: dict) -> dict:
    root = os.path.join(td, "ingest")
    _build(root, base, p, p["n0"])
    idx = DynamicHostIndex.load(root)
    n0, n_ins = p["n0"], p["n_insert"]
    queries = make_queries(p["n_queries"], base[:n0], seed=5
                           ).astype(np.float32)
    stop = threading.Event()
    errors: list = []
    searches = [0] * p["n_readers"]

    def reader(slot: int):
        rng = np.random.default_rng(slot)
        while not stop.is_set():
            try:
                ids, _ = idx.search(queries[rng.integers(0, len(queries))],
                                    K, L=L, w=W)
                if len(ids) != K:
                    raise AssertionError(f"short result: {len(ids)}")
                searches[slot] += 1
            except Exception as e:       # noqa: BLE001 — accounting drill
                errors.append(repr(e))
                return

    threads = [threading.Thread(target=reader, args=(i,))
               for i in range(p["n_readers"])]
    for t in threads:
        t.start()
    t0 = time.perf_counter()
    try:
        for i in range(n_ins):
            idx.insert(base[n0 + i])
    finally:
        ingest_wall = time.perf_counter() - t0
        stop.set()
        for t in threads:
            t.join(timeout=30)
    # post-ingest consistency: every inserted vector self-findable
    self_hits = 0
    probe = range(0, n_ins, max(1, n_ins // 20))
    for i in probe:
        ids, _ = idx.search(base[n0 + i].astype(np.float32), 1, L=L)
        self_hits += int(len(ids) and int(ids[0]) == n0 + i)
    dangling = 0
    for node in range(idx.n):
        _, nbrs, _ = idx._read_node(node)
        live = nbrs[nbrs >= 0]
        dangling += int((live >= idx.n).any())
    crc_mismatches = int(idx.cache.counters.crc_mismatches)
    idx.flush()
    idx.close()
    return dict(
        n_inserted=n_ins,
        insert_qps=n_ins / ingest_wall,
        search_qps=sum(searches) / ingest_wall,
        concurrent_searches=int(sum(searches)),
        reader_errors=errors,
        self_recall=self_hits / len(list(probe)),
        dangling_edges=dangling,
        crc_mismatches=crc_mismatches)


# ---------------------------------------------------------------------------
# phase 2: zero-downtime compaction swap
# ---------------------------------------------------------------------------


def bench_compaction_swap(td: str, base: np.ndarray, p: dict) -> dict:
    v1 = os.path.join(td, "swap_v1")
    _build(v1, base, p, p["n0"])
    n0, n_ins, n_del = p["n0"], p["swap_inserts"], p["swap_deletes"]
    deleted = list(range(0, n_del * 7, 7))
    # the ingest runs on a sibling COPY so the served v1 bytes never move
    work = os.path.join(td, "swap_work")
    shutil.copytree(v1, work)
    widx = DynamicHostIndex.load(work)
    for i in range(n_ins):
        widx.insert(base[n0 + i])
    for lbl in deleted:
        widx.delete(lbl)
    widx.flush()
    v2 = os.path.join(td, "swap_v2")
    widx.compact(v2, relabel=True)
    widx.close()
    # serve v1 under continuous load, swap to v2 mid-stream
    pool = WarmIndexPool({"live": v1}, cache_bytes=4 << 20)
    svc = RetrievalService(pool, num_workers=2, max_batch=8,
                           max_wait_ms=1.0, L=L, w=W)
    queries = make_queries(p["n_queries"], base[:n0], seed=9
                           ).astype(np.float32)
    stop = threading.Event()
    dropped: list = []
    completed = [0] * p["swap_clients"]

    def client(slot: int):
        rng = np.random.default_rng(100 + slot)
        while not stop.is_set():
            try:
                r = svc.submit_wait(queries[rng.integers(0, len(queries))],
                                    corpus="live", k=K, timeout=30.0)
                if len(r.result) != K:
                    raise AssertionError("short result")
                completed[slot] += 1
            except Exception as e:       # noqa: BLE001 — accounting drill
                dropped.append(repr(e))
                return

    # recall baseline on v1 (pre-swap truth: the original corpus)
    gt1 = np.asarray(pq.groundtruth(queries, base[:n0], K))
    got1 = np.stack([svc.submit_wait(q, corpus="live", k=K).result
                     for q in queries])
    recall_before = float(recall_at(got1, gt1, K))
    clients = [threading.Thread(target=client, args=(i,))
               for i in range(p["swap_clients"])]
    for t in clients:
        t.start()
    time.sleep(0.3)                      # let the stream establish
    swap_load_s = svc.swap("live", v2)
    time.sleep(0.3)                      # serve past the switch point
    stop.set()
    for t in clients:
        t.join(timeout=30)
    # recall on v2 (post-swap truth: grown corpus minus the deleted rows)
    live_rows = np.asarray([i for i in range(n0 + n_ins)
                            if i not in set(deleted)])
    corpus2 = base[live_rows]
    gt2 = live_rows[np.asarray(pq.groundtruth(queries, corpus2, K))]
    got2 = np.stack([svc.submit_wait(q, corpus="live", k=K).result
                     for q in queries])
    recall_after = float(recall_at(got2, gt2, K))
    deleted_served = int(sum(int(x) in set(deleted)
                             for row in got2 for x in row))
    st = pool.stats()
    svc.stop()
    pool.close()
    return dict(
        swap_load_s=swap_load_s,
        completed_during_drill=int(sum(completed)),
        dropped=dropped,
        recall_before_swap=recall_before,
        recall_after_swap=recall_after,
        deleted_rows_served_after_swap=deleted_served,
        pool=dict(swaps=st["swaps"], retired_at_snapshot=st["retired"]))


# ---------------------------------------------------------------------------
# phase 3: kill-at-every-offset crash drill
# ---------------------------------------------------------------------------


def _workload(p: dict, base: np.ndarray):
    """The scripted mutation sequence: each op is (kind, payload)."""
    n0 = p["drill_n0"]
    ops = [("insert", n0 + i) for i in range(p["drill_inserts"])]
    ops += [("delete", 11 * (j + 1)) for j in range(p["drill_deletes"])]
    ops += [("flush", None)]
    return ops


def _apply(idx: DynamicHostIndex, op, base: np.ndarray):
    kind, arg = op
    if kind == "insert":
        idx.insert(base[arg])
    elif kind == "delete":
        idx.delete(arg)
    else:
        idx.flush()


def _state_key(idx: DynamicHostIndex):
    return (int(idx.meta["n"]), frozenset(idx.tombstones))


def _oracle_snapshots(pristine: str, td: str, ops, base, queries):
    """Reference states: after each op PREFIX (flushed), the search
    results a recovered index must reproduce bit-for-bit."""
    oracles = {}
    for j in range(len(ops) + 1):
        d = os.path.join(td, f"oracle{j}")
        shutil.copytree(pristine, d)
        idx = DynamicHostIndex.load(d)
        for op in ops[:j]:
            _apply(idx, op, base)
        idx.flush()
        key = _state_key(idx)
        if key not in oracles:
            ids = np.stack([idx.search(q, K, L=L, w=W)[0]
                            for q in queries])
            oracles[key] = dict(after_ops=j, ids=ids)
        idx.close()
    return oracles


def bench_crash_drill(td: str, base: np.ndarray, p: dict) -> dict:
    pristine = os.path.join(td, "drill_pristine")
    _build(pristine, base, p, p["drill_n0"], seed=1)
    ops = _workload(p, base)
    queries = make_queries(6, base[:p["drill_n0"]], seed=3
                           ).astype(np.float32)
    oracles = _oracle_snapshots(pristine, td, ops, base, queries)
    # enumeration pass: count every crash point in the whole workload
    enum_dir = os.path.join(td, "drill_enum")
    shutil.copytree(pristine, enum_dir)
    ks = KillSwitch()
    idx = DynamicHostIndex.load(enum_dir, kill=ks)
    for op in ops:
        _apply(idx, op, base)
    idx.close()
    total = ks.count
    failures: list = []
    recovered_states: dict = {}
    rolled_back = rolled_forward = 0
    t0 = time.perf_counter()
    for at in range(1, total + 1):
        d = os.path.join(td, "drill_case")
        shutil.rmtree(d, ignore_errors=True)
        shutil.copytree(pristine, d)
        k = KillSwitch(at=at)
        h = DynamicHostIndex.load(d, kill=k)
        crash_label = None
        try:
            for op in ops:
                _apply(h, op, base)
        except CrashPoint as e:
            crash_label = e.label
        h.abandon()
        try:
            r = DynamicHostIndex.load(d)
        except Exception as e:           # noqa: BLE001 — the drill verdict
            failures.append(f"at={at} ({crash_label}): reload failed: {e!r}")
            continue
        rolled_back += r.recovery["rolled_back"]
        rolled_forward += r.recovery["rolled_forward"]
        key = _state_key(r)
        recovered_states[key] = recovered_states.get(key, 0) + 1
        if key not in oracles:
            failures.append(f"at={at} ({crash_label}): recovered to "
                            f"non-oracle state {key}")
            r.close()
            continue
        bad = False
        if r.wal.size != 0:
            failures.append(f"at={at}: journal not checkpointed")
            bad = True
        for node in range(r.n):          # no dangling edges anywhere
            _, nbrs, _ = r._read_node(node)
            live = nbrs[nbrs >= 0]
            if (live >= r.n).any():
                failures.append(f"at={at} ({crash_label}): dangling edge "
                                f"at node {node}")
                bad = True
                break
        if not bad:
            ids = np.stack([r.search(q, K, L=L, w=W)[0] for q in queries])
            if not np.array_equal(ids, oracles[key]["ids"]):
                failures.append(f"at={at} ({crash_label}): search differs "
                                f"from oracle after ops "
                                f"{oracles[key]['after_ops']}")
            if r.cache.counters.crc_mismatches:
                failures.append(f"at={at} ({crash_label}): CRC mismatch "
                                "on recovered index")
        r.close()
    return dict(
        crash_points=total,
        wall_s=time.perf_counter() - t0,
        ops=len(ops),
        recovered_ok=total - len(failures),
        recovery_rate=(total - len(failures)) / max(total, 1),
        rolled_back_total=rolled_back,
        rolled_forward_total=rolled_forward,
        distinct_recovered_states=len(recovered_states),
        failures=failures)


# ---------------------------------------------------------------------------
# verdicts + report
# ---------------------------------------------------------------------------


def drill_failures(rep: dict) -> list:
    fails = []
    ing = rep["concurrent_ingest"]
    if ing["reader_errors"]:
        fails.append(f"ingest readers errored: {ing['reader_errors'][:3]}")
    if ing["crc_mismatches"]:
        fails.append(f"{ing['crc_mismatches']} CRC mismatches under ingest")
    if ing["dangling_edges"]:
        fails.append(f"{ing['dangling_edges']} dangling edges after ingest")
    if ing["self_recall"] < 0.8:
        fails.append(f"post-ingest self recall {ing['self_recall']:.2f}")
    sw = rep["compaction_swap"]
    if sw["dropped"]:
        fails.append(f"swap dropped requests: {sw['dropped'][:3]}")
    if sw["deleted_rows_served_after_swap"]:
        fails.append(f"{sw['deleted_rows_served_after_swap']} tombstoned "
                     "rows served after the swap")
    if sw["recall_after_swap"] < sw["recall_before_swap"] - 0.15:
        fails.append(f"recall collapsed across the swap: "
                     f"{sw['recall_before_swap']:.3f} -> "
                     f"{sw['recall_after_swap']:.3f}")
    if sw["pool"]["swaps"] != 1:
        fails.append("pool recorded no swap")
    cd = rep["crash_drill"]
    if cd["recovery_rate"] < 1.0:
        fails.append(f"crash drill recovered {cd['recovered_ok']}/"
                     f"{cd['crash_points']}: {cd['failures'][:5]}")
    if cd["distinct_recovered_states"] < 2:
        fails.append("crash drill never exercised distinct oracle states")
    return fails


def run_all(p: dict, tag: str) -> dict:
    from benchmarks import common as C
    base = make_clustered(p["n0"] + p["n_insert"] + 64, p["dim"], seed=2)
    rep = {"schema_version": SCHEMA_VERSION, "mode": tag,
           "workload": dict(p, k=K, L=L, w=W),
           "provenance": C.provenance("ingest")}
    with tempfile.TemporaryDirectory() as td:
        rep["concurrent_ingest"] = bench_concurrent_ingest(td, base, p)
        rep["compaction_swap"] = bench_compaction_swap(td, base, p)
        rep["crash_drill"] = bench_crash_drill(td, base, p)
    rep["failures"] = drill_failures(rep)
    rep["headline"] = dict(
        insert_qps=rep["concurrent_ingest"]["insert_qps"],
        concurrent_search_qps=rep["concurrent_ingest"]["search_qps"],
        swap_zero_dropped=not rep["compaction_swap"]["dropped"],
        recall_before_swap=rep["compaction_swap"]["recall_before_swap"],
        recall_after_swap=rep["compaction_swap"]["recall_after_swap"],
        crash_points=rep["crash_drill"]["crash_points"],
        crash_recovery_rate=rep["crash_drill"]["recovery_rate"],
        all_invariants_hold=not rep["failures"])
    return rep


def all_benchmarks():
    rep = run_all(FULL, "full")
    dest = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_ingest.json"))
    with open(dest, "w") as f:
        json.dump(rep, f, indent=1)
    print(f"[bench_ingest] wrote {dest}")
    if rep["failures"]:
        for msg in rep["failures"]:
            print(f"[bench_ingest] FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    h = rep["headline"]
    return [
        ("ingest_insert_qps", h["insert_qps"],
         f"search_qps={h['concurrent_search_qps']:.0f}"),
        ("ingest_swap_zero_dropped", float(h["swap_zero_dropped"]),
         f"recall={h['recall_before_swap']:.3f}->"
         f"{h['recall_after_swap']:.3f}"),
        ("ingest_crash_recovery_rate", h["crash_recovery_rate"],
         f"points={h['crash_points']}"),
    ]


def quick_smoke() -> int:
    t0 = time.perf_counter()
    rep = run_all(QUICK, "quick")
    wall = time.perf_counter() - t0
    dest = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                        "BENCH_ingest.json"))
    with open(dest, "w") as f:
        json.dump(rep, f, indent=1)
    if rep["failures"]:
        for msg in rep["failures"]:
            print(f"[bench_ingest --quick] FAIL: {msg}", file=sys.stderr)
        return 1
    h = rep["headline"]
    print(f"[bench_ingest --quick] all ingest invariants hold ({wall:.1f}s):"
          f" insert_qps={h['insert_qps']:.0f}"
          f" search_qps={h['concurrent_search_qps']:.0f}"
          f" crash_points={h['crash_points']}"
          f" recovery={h['crash_recovery_rate']:.0%}"
          f" swap_recall={h['recall_before_swap']:.2f}->"
          f"{h['recall_after_swap']:.2f}")
    return 0


if __name__ == "__main__":
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.3f},{extra}")
