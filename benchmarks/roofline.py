"""Roofline analysis (deliverable g): merge dry-run artifacts with the
analytic loop-corrected estimator into the §Roofline table.

    PYTHONPATH=src python -m benchmarks.roofline [--pod pod1] [--md out.md]

Three terms per (arch x shape), single-pod mesh by default:
    t_compute    = FLOPs / (chips * 197 TFLOP/s)
    t_memory     = HBM bytes / (chips * 819 GB/s)
    t_collective = collective bytes / (chips * 50 GB/s per link)

Two sources are reported side by side:
  * RAW: compiled.cost_analysis() + HLO collective parse — faithful to the
    compiled artifact but loop-DEDUPLICATED (XLA counts scan bodies once).
  * EST: launch/flops.py analytic, loop-true, sharding-aware (used for the
    headline fractions and the useful-work ratio MODEL_FLOPS/EST_FLOPS).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs.registry import get_arch
from repro.launch.flops import (HBM_BW, LINK_BW, PEAK_FLOPS, cell_terms)
from repro.launch.inputs import model_flops


def load_artifacts(art_dir: str, pod: str):
    rows = {}
    for f in sorted(glob.glob(os.path.join(art_dir, f"*__{pod}.json"))):
        r = json.load(open(f))
        rows[(r["arch"], r["shape"])] = r
    return rows


def analyze(pod: str = "pod1", art_dir: str = "benchmarks/artifacts/dryrun"):
    arts = load_artifacts(art_dir, pod)
    chips = 512 if pod.startswith("pod2") else 256
    dp = 32 if pod.startswith("pod2") else 16
    opt = "opt" in pod                      # optimized config: cp-attn etc.
    out = []
    for (arch_id, shape_name), r in arts.items():
        if r.get("skipped"):
            out.append(dict(arch=arch_id, shape=shape_name, skipped=True,
                            reason=r.get("reason", "")))
            continue
        arch = get_arch(arch_id)
        shape = arch.shape(shape_name)
        mode_b = r.get("meta", {}).get("mode") == "B"
        opts = {}
        if arch.family == "lm" and opt:
            opts["cp_attention"] = True
        if arch.family == "ann" and opt:
            opts["int8_adc"] = True
        est = cell_terms(arch, shape, chips=chips, model_ways=16,
                         dp_ways=dp, mode_b=mode_b, **opts)
        mf = model_flops(arch, shape) / chips
        raw_flops = r.get("cost", {}).get("flops", 0.0)
        raw_bytes = r.get("cost", {}).get("bytes accessed", 0.0)
        raw_coll = sum(v["bytes"] for v in r.get("collectives", {}).values()
                       if isinstance(v, dict))
        mem = r.get("memory", {})
        hbm_gb = (mem.get("argument_size_in_bytes", 0)
                  + mem.get("temp_size_in_bytes", 0)
                  + mem.get("output_size_in_bytes", 0)
                  - mem.get("alias_size_in_bytes", 0)) / 1e9
        dom = est["bottleneck"]
        t_dom = est[dom]
        bound = max(est["t_compute"], est["t_memory"], est["t_collective"])
        # roofline fraction = time doing USEFUL flops at peak / bound time
        t_useful = mf / PEAK_FLOPS
        frac = t_useful / bound if bound else 0.0
        out.append(dict(
            arch=arch_id, shape=shape_name, chips=chips,
            est_flops=est["flops"], est_hbm=est["hbm_bytes"],
            est_coll=est["coll_bytes"],
            t_compute=est["t_compute"], t_memory=est["t_memory"],
            t_collective=est["t_collective"], bottleneck=dom,
            model_flops_dev=mf,
            useful_ratio=mf / est["flops"] if est["flops"] else 0.0,
            roofline_frac=frac,
            raw_flops=raw_flops, raw_bytes=raw_bytes, raw_coll=raw_coll,
            mem_gb=hbm_gb, compile_s=r.get("t_compile_s"),
        ))
    return out


def to_markdown(rows, pod):
    lines = [
        f"### Roofline — {pod} "
        f"({512 if pod == 'pod2' else 256} chips, v5e: 197 TF/s bf16, "
        "819 GB/s HBM, 50 GB/s/link)",
        "",
        "| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | bound |"
        " useful | roofline | fit GB | raw GFLOP/dev |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                         f"skip | — | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute']*1e3:.2f} | "
            f"{r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | "
            f"{r['bottleneck'][2:]} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_frac']:.3f} | "
            f"{r['mem_gb']:.1f} | {r['raw_flops']/1e9:.0f} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--pod", default="pod1")
    ap.add_argument("--md")
    ap.add_argument("--json")
    args = ap.parse_args(argv)
    rows = analyze(args.pod)
    md = to_markdown(rows, args.pod)
    print(md)
    if args.md:
        with open(args.md, "w") as f:
            f.write(md + "\n")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)


if __name__ == "__main__":
    main()
