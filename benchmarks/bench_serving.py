"""Multi-tenant serving benchmark: warm-index pool sweep + exact rerank.

Zipf multi-corpus section (the paper's §2.2/§4.4 RAG-retriever claim made
measurable): N sub-corpora share one PQ-centroid set; a zipf-distributed
request stream is served through `RetrievalService` at three pool budgets —

  slots_1   budget fits ONE index   (the old single-active IndexManager)
  slots_2   budget fits two indices (partial warmth)
  all_warm  budget fits every index (AiSAQ's cheap-co-residency regime)

Every config serves the IDENTICAL stream with the same per-index DRAM
(block-cache budget + residency, well under the paper's ~10 MB knob);
only the number of simultaneously-warm indices changes.  Reported per
config: QPS, p50/p99, switch (pool-miss) count, eviction count, and a
results-identical cross-check — eviction must never change answers.

Rerank section: the exact rerank tier on the main bench corpus — recall@10
for {PQ-only, rerank, traversal-pool} tiers, bit-identity vs the extended
scalar oracle, and the rerank I/O cost.

    PYTHONPATH=src:. python benchmarks/bench_serving.py          # full
    PYTHONPATH=src:. python benchmarks/bench_serving.py --quick  # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks import common as C
from repro.core.index_io import HostIndex, recall_at
from repro.serving.pool import WarmIndexPool
from repro.serving.service import BackpressureError, RetrievalService

SCHEMA_VERSION = 1
N_CORPORA = 6
N_REQUESTS = 600
ZIPF_A = 1.1
CACHE_BYTES = 1 << 20       # per-handle block-cache budget (<< 10 MB/index)
K, L, W = 10, 32, 4
RERANK = 40


def zipf_stream(n_corpora: int, n_requests: int, seed: int = 7):
    """Deterministic zipf corpus stream: P(rank r) ~ 1 / r^ZIPF_A."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_corpora + 1) ** ZIPF_A
    p /= p.sum()
    return rng.choice(n_corpora, size=n_requests, p=p)


def _probe_sizes(paths):
    """(per-entry bytes, shared-centroid bytes) from ONE probe load."""
    pool = WarmIndexPool(paths, cache_bytes=CACHE_BYTES)
    name = next(iter(paths))
    pool.ensure(name)
    per, cent = pool.entry_bytes(name), pool.centroid_bytes()
    pool.close()
    return per, cent


def _budget(per: int, cent: int, n_slots: int) -> int:
    """Byte budget fitting exactly n_slots handles + the shared centroids."""
    return cent + n_slots * per + per // 2


def _run_config(paths, budget, stream, queries_per_corpus) -> dict:
    """Serve the full stream through one pool config; report telemetry.

    Pools are `strict` (the byte budget is a hard admission resource) and
    every config runs ONE worker: on this GIL-bound host path a second
    search thread only adds contention noise, and the sweep is about the
    POOL dimension — what changes across configs is purely how many
    handles (and their block caches) stay warm.  An evicted corpus pays
    load + cold-cache on its next batch; a warm one pays neither."""
    pool = WarmIndexPool(paths, budget_bytes=budget, cache_bytes=CACHE_BYTES,
                         strict=True)
    svc = RetrievalService(pool, num_workers=1, max_batch=8, max_wait_ms=2.0,
                           max_queue_depth=2 * len(stream), L=L, w=W)
    names = sorted(paths)
    q_next = {n: 0 for n in names}
    t0 = time.perf_counter()
    reqs = []
    for c in stream:
        name = names[c]
        qs = queries_per_corpus[name]
        reqs.append((name, svc.submit(qs[q_next[name] % len(qs)],
                                      corpus=name, k=K)))
        q_next[name] += 1
    for _, r in reqs:
        r.event.wait(120.0)
        assert r.error is None and r.result is not None, r.error
    wall = time.perf_counter() - t0
    st = svc.stats()
    ps = pool.stats()
    out = dict(
        budget_bytes=int(budget) if budget is not None else None,
        wall_s=wall, qps=len(stream) / wall,
        p50_ms=st["p50_ms"], p99_ms=st["p99_ms"],
        switches=st["total_switches"],
        pool=dict(hits=ps["hits"], misses=ps["misses"],
                  evictions=ps["evictions"],
                  budget_overflow=ps["budget_overflow"],
                  centroid_shares=ps["centroid_shares"],
                  strict_waits=ps["strict_waits"],
                  used_bytes=ps["used_bytes"], open=ps["open"]),
        per_corpus={c: dict(completed=v["completed"], switches=v["switches"],
                            p99_ms=v.get("p99_ms"), qps=v["qps"])
                    for c, v in st["corpora"].items()})
    results = {i: np.asarray(r.result) for i, (_, r) in enumerate(reqs)}
    svc.stop()
    pool.close()
    return out, results


def bench_zipf_multicorpus() -> dict:
    paths = C.ensure_subcorpora(n_sub=N_CORPORA)
    base, _, _ = C.corpus()
    sub_n = 2000
    from repro.data.vectors import make_queries
    queries_per_corpus = {
        name: make_queries(32, base[i * sub_n:(i + 1) * sub_n], seed=10 + i)
        for i, name in enumerate(sorted(paths))}
    stream = zipf_stream(N_CORPORA, N_REQUESTS)
    section = dict(n_corpora=N_CORPORA, n_requests=N_REQUESTS, zipf_a=ZIPF_A,
                   cache_bytes_per_index=CACHE_BYTES, k=K, L=L, w=W,
                   configs={})
    # per-index DRAM: residency + cache budget, centroids counted once
    per, cent = _probe_sizes(paths)
    section["per_index_bytes"] = per
    section["shared_centroid_bytes"] = cent
    budgets = {"slots_1": _budget(per, cent, 1),
               "slots_2": _budget(per, cent, 2),
               "all_warm": _budget(per, cent, N_CORPORA)}
    all_results = {}
    for cfg, budget in budgets.items():
        r, results = _run_config(paths, budget, stream, queries_per_corpus)
        section["configs"][cfg] = r
        all_results[cfg] = results
        print(f"[bench_serving] {cfg:8s} qps={r['qps']:8.1f} "
              f"p99={r['p99_ms']:7.2f}ms switches={r['switches']:4d} "
              f"evictions={r['pool']['evictions']}")
    # eviction must never change answers: identical ids across configs
    ident = all(
        np.array_equal(all_results["slots_1"][i], all_results["all_warm"][i])
        and np.array_equal(all_results["slots_2"][i],
                           all_results["all_warm"][i])
        for i in range(N_REQUESTS))
    s1, aw = section["configs"]["slots_1"], section["configs"]["all_warm"]
    section["headline"] = dict(
        p99_single_slot_ms=s1["p99_ms"], p99_all_warm_ms=aw["p99_ms"],
        p99_speedup_x=s1["p99_ms"] / max(aw["p99_ms"], 1e-9),
        qps_single_slot=s1["qps"], qps_all_warm=aw["qps"],
        switches_single_slot=s1["switches"],
        switches_all_warm=aw["switches"],
        all_warm_p99_below_single_slot=bool(aw["p99_ms"] < s1["p99_ms"]),
        results_identical_across_budgets=bool(ident))
    return section


def bench_rerank(m: int = C.DEFAULT_M) -> dict:
    """Exact rerank tier vs PQ-only vs traversal pool on the bench corpus."""
    paths = C.ensure_indices(ms=(m,), modes=("aisaq",))
    base, q, gt = C.corpus()
    idx = HostIndex.load(paths[("aisaq", m)])
    out = dict(k=K, L=40, rerank_depth=RERANK, tiers={})
    tier_ids = {}
    for tier, rr in (("pq_only", 0), ("rerank", RERANK),
                     ("traversal_pool", None)):
        t0 = time.perf_counter()
        ids, stats = idx.search_batch(q, K, L=40, rerank=rr)
        wall = time.perf_counter() - t0
        tier_ids[tier] = ids
        out["tiers"][tier] = dict(
            recall10=recall_at(ids, gt, K), wall_s=wall,
            qps=len(q) / wall,
            rerank_ios_per_query=float(np.mean([s.rerank_ios
                                                for s in stats])))
    ref_ids, _ = idx.search_batch_ref(q, K, L=40, rerank=RERANK)
    out["identical_to_oracle"] = bool(
        np.array_equal(tier_ids["rerank"], ref_ids))
    out["recall_lift_vs_pq_only"] = \
        out["tiers"]["rerank"]["recall10"] - out["tiers"]["pq_only"]["recall10"]
    idx.close()
    return out


def all_benchmarks():
    rows = []
    report = {"schema_version": SCHEMA_VERSION,
              "corpus": dict(n=C.N, dim=C.DIM, R=C.R)}
    report["zipf_multicorpus"] = z = bench_zipf_multicorpus()
    for cfg, r in z["configs"].items():
        rows.append((f"serving_{cfg}_qps", r["qps"],
                     f"p99={r['p99_ms']:.2f}ms_switches={r['switches']}"))
    rows.append(("serving_p99_speedup_all_warm",
                 z["headline"]["p99_speedup_x"],
                 f"identical={z['headline']['results_identical_across_budgets']}"))
    report["rerank"] = rr = bench_rerank()
    for tier, t in rr["tiers"].items():
        rows.append((f"rerank_{tier}_recall10", t["recall10"],
                     f"qps={t['qps']:.0f}"))
    rows.append(("rerank_recall_lift", rr["recall_lift_vs_pq_only"],
                 f"oracle_identical={rr['identical_to_oracle']}"))
    report["headline"] = dict(
        all_warm_p99_below_single_slot=z["headline"]
        ["all_warm_p99_below_single_slot"],
        p99_speedup_x=z["headline"]["p99_speedup_x"],
        rerank_recall10=rr["tiers"]["rerank"]["recall10"],
        pq_only_recall10=rr["tiers"]["pq_only"]["recall10"],
        rerank_identical_to_oracle=rr["identical_to_oracle"])
    report["provenance"] = C.provenance("serving")
    dest = os.path.join(os.path.dirname(__file__), "..",
                        "BENCH_serving.json")
    with open(os.path.abspath(dest), "w") as f:
        json.dump(report, f, indent=1)
    print(f"[bench_serving] wrote {os.path.abspath(dest)}")
    return rows


# ---------------------------------------------------------------------------
# CI smoke
# ---------------------------------------------------------------------------


def quick_smoke() -> int:
    """CI smoke: tiny corpora built on the fly in a tempdir (the cached
    `benchmarks/artifacts/bench_idx` indices are NOT rebuilt — CI has no
    artifact cache and must stay fast).  Asserts the serving invariants:
    pool eviction correctness, admission control, switch-count ordering,
    and rerank tier bit-identity + recall dominance."""
    import tempfile

    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries

    t0 = time.perf_counter()
    failures = []
    n_sub, sub_n, d = 3, 800, 32
    base = make_clustered(n_sub * sub_n, d, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=8, iters=6)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    with tempfile.TemporaryDirectory() as td:
        paths = {}
        qpc = {}
        for i in range(n_sub):
            sl = slice(i * sub_n, (i + 1) * sub_n)
            g = build_vamana(base[sl], R=12, L=24, seed=i)
            p = os.path.join(td, f"sub{i}")
            write_index(p, vectors=base[sl], graph=g, centroids=cents,
                        codes=codes[sl], metric="l2", mode="aisaq")
            paths[f"sub{i}"] = p
            qpc[f"sub{i}"] = make_queries(8, base[sl], seed=20 + i)
        refs = {}
        for name, p in paths.items():
            idx = HostIndex.load(p)
            refs[name], _ = idx.search_batch(qpc[name], 5, L=24, w=W)
            idx.close()
        stream = zipf_stream(n_sub, 90)
        switch_counts = {}
        per, cent = _probe_sizes(paths)
        for cfg, slots in (("slots_1", 1), ("all_warm", n_sub)):
            pool = WarmIndexPool(paths, cache_bytes=CACHE_BYTES,
                                 budget_bytes=_budget(per, cent, slots),
                                 strict=True)
            svc = RetrievalService(pool, num_workers=2, max_batch=8,
                                   max_wait_ms=1.0, max_queue_depth=500,
                                   L=24, w=W)
            names = sorted(paths)
            reqs = []
            for i, c in enumerate(stream):
                name = names[c]
                reqs.append((name, i, svc.submit(qpc[name][i % 8],
                                                 corpus=name, k=5)))
            for name, i, r in reqs:
                r.event.wait(30.0)
                if r.result is None:
                    failures.append(f"{cfg}: request {i} never completed "
                                    f"({r.error})")
                elif not np.array_equal(r.result, refs[name][i % 8]):
                    failures.append(f"{cfg}: request {i} wrong ids "
                                    "(eviction corrupted a search)")
            st = svc.stats()
            switch_counts[cfg] = st["total_switches"]
            if cfg == "slots_1" and st["pool"]["evictions"] == 0:
                failures.append("slots_1: no evictions — budget not binding")
            svc.stop()
            pool.close()
        if not switch_counts["all_warm"] < switch_counts["slots_1"]:
            failures.append(
                f"all-warm switches ({switch_counts['all_warm']}) not below "
                f"single-slot ({switch_counts['slots_1']})")
        # admission control rejects when the queue is at depth
        pool = WarmIndexPool(paths, cache_bytes=CACHE_BYTES)
        svc = RetrievalService(
            pool, num_workers=1, max_queue_depth=2, max_wait_ms=0.5,
            search_fn=lambda idx, Q, k:
            (time.sleep(0.15), np.zeros((Q.shape[0], k), np.int64))[1])
        rejected = 0
        for _ in range(10):
            try:
                svc.submit(qpc["sub0"][0], corpus="sub0", k=5)
            except BackpressureError:
                rejected += 1
        if rejected == 0:
            failures.append("admission control never rejected")
        svc.stop()
        pool.close()
        # rerank tier: oracle bit-identity + recall dominance over PQ-only
        idx = HostIndex.load(paths["sub0"])
        qq = qpc["sub0"]
        gt = np.asarray(pq.groundtruth(qq, base[:sub_n], 5))
        ids_rr, _ = idx.search_batch(qq, 5, L=24, rerank=20)
        ids_ref, _ = idx.search_batch_ref(qq, 5, L=24, rerank=20)
        ids_pq, _ = idx.search_batch(qq, 5, L=24, rerank=0)
        if not np.array_equal(ids_rr, ids_ref):
            failures.append("rerank: batched != scalar oracle")
        r_rr, r_pq = recall_at(ids_rr, gt, 5), recall_at(ids_pq, gt, 5)
        if r_rr < r_pq:
            failures.append(f"rerank recall {r_rr:.3f} < PQ-only {r_pq:.3f}")
        idx.close()
    wall = time.perf_counter() - t0
    if failures:
        for msg in failures:
            print(f"[bench_serving --quick] FAIL: {msg}", file=sys.stderr)
        return 1
    print(f"[bench_serving --quick] all serving invariants hold ({wall:.1f}s)")
    return 0


if __name__ == "__main__":
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.3f},{extra}")
