"""Storage fault-tolerance drill: seeded faults against the serving stack.

Serves the 6-corpus zipf workload (bench_serving's stream) through
`RetrievalService` with a deterministic `FaultInjector` under every
corpus's block reads:

  * every corpus sees transient EIO at ~1e-3 per read — the retry layer
    must absorb these invisibly (completed answers stay bit-identical to
    the fault-free references),
  * ONE corpus additionally serves flipped bits from its entry-point
    block for a finite number of reads (a sick region that later heals):
    the CRC layer turns those reads into `CorruptBlockError`, consecutive
    failures quarantine the corpus, submits fail fast with
    `CorpusUnhealthyError`, and a half-open probe recovers it once the
    region heals.

Every request must end in exactly one bucket — completed, io_error,
unhealthy_rejected, expired — and the buckets must sum to the stream
length (100% completion-or-clean-rejection).  Worker deaths must be 0.

A separate fault-free section measures the checksum-verification cost on
the warm path (cache-hit serving must pay ~nothing; the report asserts
< 5%) and, informatively, on the cold path where every read is verified.

    PYTHONPATH=src:. python benchmarks/bench_faults.py          # full
    PYTHONPATH=src:. python benchmarks/bench_faults.py --quick  # CI smoke
"""
from __future__ import annotations

import json
import os
import sys
import time

import numpy as np

from benchmarks import common as C
from repro.core.faults import FaultInjector, FaultPlan
from repro.core.index_io import HostIndex
from repro.serving.pool import CorpusUnhealthyError, WarmIndexPool
from repro.serving.service import BackpressureError, RetrievalService

SCHEMA_VERSION = 1
N_CORPORA = 6
N_REQUESTS = 600
ZIPF_A = 1.1
CACHE_BYTES = 1 << 20
K, L, W = 10, 32, 4
EIO_RATE = 1e-3            # transient-EIO probability per (offset, attempt)
CORRUPT_READS = 8          # sick block serves this many flipped-bit reads
FAULT_SEED = 1234


def zipf_stream(n_corpora: int, n_requests: int, seed: int = 7):
    """Deterministic zipf corpus stream (same law as bench_serving)."""
    rng = np.random.default_rng(seed)
    p = 1.0 / np.arange(1, n_corpora + 1) ** ZIPF_A
    p /= p.sum()
    return rng.choice(n_corpora, size=n_requests, p=p)


def entry_block(path: str) -> int:
    """I/O-unit index of the first entry point's chunk — corrupting it
    guarantees every search on the corpus hits the fault."""
    idx = HostIndex.load(path)
    try:
        ep = int(idx.meta["entry_points"][0])
        return idx.layout.file_offset(ep) // idx.layout.io_bytes
    finally:
        idx.close()


def _fault_free_refs(paths, queries_per_corpus, k, L, w):
    """Per-corpus reference ids with no injector: the bit-identity bar."""
    refs = {}
    for name, p in paths.items():
        idx = HostIndex.load(p, cache_bytes=CACHE_BYTES)
        refs[name], _ = idx.search_batch(queries_per_corpus[name], k, L=L,
                                         w=w)
        idx.close()
    return refs


def run_drill(paths, queries_per_corpus, stream, *, k, L, w,
              eio_rate=EIO_RATE, corrupt_reads=CORRUPT_READS,
              quarantine_after=3, cooldown_s=0.5,
              recovery_timeout_s=30.0) -> dict:
    """The drill proper: synchronous zipf stream through a service whose
    pool reads through per-corpus injectors; one corpus's entry block is
    transiently corrupt.  Returns the full accounting dict; raises
    nothing — callers assert on the dict so full/quick share one body."""
    names = sorted(paths)
    sick = names[0]                      # zipf rank 0: the busiest corpus
    refs = _fault_free_refs(paths, queries_per_corpus, k, L, w)
    sick_block = entry_block(paths[sick])
    injectors = {
        n: FaultInjector(FaultPlan(
            seed=FAULT_SEED + i, eio_rate=eio_rate,
            corrupt_blocks=({sick_block: corrupt_reads} if n == sick
                            else {})))
        for i, n in enumerate(names)}
    pool = WarmIndexPool(paths, cache_bytes=CACHE_BYTES,
                         preadv_factory=lambda n: injectors[n],
                         quarantine_after=quarantine_after,
                         quarantine_cooldown_s=cooldown_s,
                         probe_timeout_s=5.0)
    svc = RetrievalService(pool, num_workers=2, max_batch=8,
                           max_wait_ms=1.0, max_queue_depth=64, L=L, w=w)
    buckets = dict(completed=0, io_error=0, unhealthy_rejected=0,
                   expired=0, backpressure=0, other_error=0)
    mismatches = 0
    q_next = {n: 0 for n in names}
    t0 = time.perf_counter()
    for c in stream:
        name = names[int(c)]
        qs = queries_per_corpus[name]
        qi = q_next[name] % len(qs)
        q_next[name] += 1
        try:
            r = svc.submit_wait(qs[qi], corpus=name, k=k, timeout=30.0)
            buckets["completed"] += 1
            if not np.array_equal(np.asarray(r.result), refs[name][qi]):
                mismatches += 1
        except CorpusUnhealthyError:
            buckets["unhealthy_rejected"] += 1
        except BackpressureError:
            buckets["backpressure"] += 1
        except TimeoutError:
            buckets["expired"] += 1
        except OSError:
            buckets["io_error"] += 1
        except Exception:                # noqa: BLE001 — accounting drill
            buckets["other_error"] += 1
    stream_wall = time.perf_counter() - t0
    # recovery phase: the sick block has healed (finite corrupt budget);
    # keep knocking until the half-open probe closes the breaker
    recovered = False
    deadline = time.monotonic() + recovery_timeout_s
    while time.monotonic() < deadline:
        try:
            svc.submit_wait(queries_per_corpus[sick][0], corpus=sick, k=k,
                            timeout=10.0)
            recovered = True
            break
        except (CorpusUnhealthyError, OSError, TimeoutError):
            time.sleep(0.05)
    workers_alive = sum(t.is_alive() for t in svc._workers)
    n_workers = len(svc._workers)
    st = svc.stats()
    sick_health = pool.health(sick)
    out = dict(
        n_requests=len(stream),
        stream_wall_s=stream_wall,
        sick_corpus=sick,
        sick_block=int(sick_block),
        buckets=buckets,
        accounted=int(sum(buckets.values())),
        completion_rate=buckets["completed"] / len(stream),
        clean_rate=(buckets["completed"] + buckets["unhealthy_rejected"]
                    + buckets["io_error"] + buckets["expired"])
        / len(stream),
        bit_identical_to_fault_free=mismatches == 0,
        mismatches=mismatches,
        worker_deaths=n_workers - workers_alive,
        recovered=recovered,
        sick_health=sick_health,
        service=dict(total_completed=st["total_completed"],
                     total_expired=st["total_expired"],
                     total_unhealthy_rejected=st["total_unhealthy_rejected"]),
        cache_totals=dict(
            read_retries=sum(v["read_retries"]
                             for v in st["pool"]["caches"].values()),
            crc_mismatches=sum(v["crc_mismatches"]
                               for v in st["pool"]["caches"].values()),
            crc_rereads=sum(v["crc_rereads"]
                            for v in st["pool"]["caches"].values())),
        injectors={n: inj.stats() for n, inj in injectors.items()})
    svc.stop()
    pool.close()
    return out


def drill_failures(d: dict) -> list:
    """The drill's pass/fail contract, shared by full and quick modes."""
    fails = []
    if d["worker_deaths"]:
        fails.append(f"{d['worker_deaths']} worker thread(s) died")
    if d["accounted"] != d["n_requests"]:
        fails.append(f"accounting leak: {d['accounted']} buckets vs "
                     f"{d['n_requests']} requests")
    if d["buckets"]["other_error"] or d["buckets"]["backpressure"]:
        fails.append(f"unclean outcomes: {d['buckets']}")
    if d["clean_rate"] < 1.0:
        fails.append(f"clean completion-or-rejection rate "
                     f"{d['clean_rate']:.4f} < 1.0")
    if not d["bit_identical_to_fault_free"]:
        fails.append(f"{d['mismatches']} completed answers differ from "
                     "fault-free references")
    if d["sick_health"]["quarantines"] < 1:
        fails.append("sick corpus was never quarantined")
    if not d["recovered"] or d["sick_health"]["recoveries"] < 1 \
            or d["sick_health"]["state"] != "healthy":
        fails.append(f"sick corpus did not recover: {d['sick_health']}")
    if d["cache_totals"]["crc_mismatches"] < 1:
        fails.append("CRC layer never caught the injected corruption")
    if d["buckets"]["io_error"] < 1:
        fails.append("persistent corruption never surfaced as io_error")
    return fails


def bench_checksum_overhead(path: str, queries: np.ndarray, *, k, L, w,
                            repeats: int = 9) -> dict:
    """Fault-free verification cost.  Warm path: the cache absorbs every
    read after warmup, so verify-on must cost ~nothing (< 5% asserted).
    Cold path: every block read pays one CRC — reported informatively.

    Both handles stay open and the timed passes INTERLEAVE (off/on per
    round, best-of-N each) so clock drift and one-off stalls hit both
    sides equally; the OS page cache is pre-warmed before either cold
    pass so first-touch misses don't masquerade as checksum cost."""
    with open(os.path.join(path, "chunks.bin"), "rb") as f:
        while f.read(1 << 20):                      # pre-warm the page cache
            pass
    idxs, cold = {}, {}
    for label, verify in (("verify_off", False), ("verify_on", None)):
        t0 = time.perf_counter()
        idx = HostIndex.load(path, cache_bytes=64 << 20,
                             verify_checksums=verify)
        idx.search_batch(queries, k, L=L, w=w)      # cold pass: all reads
        cold[label] = time.perf_counter() - t0
        idxs[label] = idx
    warm = {label: float("inf") for label in idxs}
    for _ in range(repeats):                        # warm passes: all hits
        for label, idx in idxs.items():
            t0 = time.perf_counter()
            idx.search_batch(queries, k, L=L, w=w)
            warm[label] = min(warm[label], time.perf_counter() - t0)
    timings = {label: dict(cold_s=cold[label], warm_s=warm[label])
               for label in idxs}
    for idx in idxs.values():
        idx.close()
    warm_pct = 100.0 * (warm["verify_on"] / warm["verify_off"] - 1.0)
    cold_pct = 100.0 * (cold["verify_on"] / cold["verify_off"] - 1.0)
    return dict(timings=timings,
                warm_overhead_pct=warm_pct,
                cold_overhead_pct=cold_pct,
                warm_under_5pct=bool(warm_pct < 5.0))


def _drill_corpora():
    paths = C.ensure_subcorpora(n_sub=N_CORPORA)
    base, _, _ = C.corpus()
    sub_n = 2000
    from repro.data.vectors import make_queries
    queries_per_corpus = {
        name: make_queries(32, base[i * sub_n:(i + 1) * sub_n], seed=10 + i)
        for i, name in enumerate(sorted(paths))}
    return paths, queries_per_corpus


def all_benchmarks():
    rows = []
    report = {"schema_version": SCHEMA_VERSION,
              "workload": dict(n_corpora=N_CORPORA, n_requests=N_REQUESTS,
                               zipf_a=ZIPF_A, k=K, L=L, w=W,
                               eio_rate=EIO_RATE,
                               corrupt_reads=CORRUPT_READS)}
    paths, qpc = _drill_corpora()
    stream = zipf_stream(N_CORPORA, N_REQUESTS)
    report["drill"] = d = run_drill(paths, qpc, stream, k=K, L=L, w=W)
    fails = drill_failures(d)
    report["drill"]["failures"] = fails
    rows.append(("faults_completion_rate", d["completion_rate"],
                 f"clean={d['clean_rate']:.3f}"))
    rows.append(("faults_worker_deaths", d["worker_deaths"],
                 f"quarantines={d['sick_health']['quarantines']}_"
                 f"recoveries={d['sick_health']['recoveries']}"))
    rows.append(("faults_bit_identical",
                 float(d["bit_identical_to_fault_free"]),
                 f"retries={d['cache_totals']['read_retries']}_"
                 f"crc={d['cache_totals']['crc_mismatches']}"))
    report["checksum_overhead"] = ov = bench_checksum_overhead(
        paths[sorted(paths)[1]], qpc[sorted(paths)[1]], k=K, L=L, w=W)
    rows.append(("faults_crc_warm_overhead_pct", ov["warm_overhead_pct"],
                 f"cold={ov['cold_overhead_pct']:.1f}pct"))
    report["headline"] = dict(
        drill_passed=not fails,
        completion_rate=d["completion_rate"],
        clean_rate=d["clean_rate"],
        worker_deaths=d["worker_deaths"],
        quarantines=d["sick_health"]["quarantines"],
        recoveries=d["sick_health"]["recoveries"],
        bit_identical_to_fault_free=d["bit_identical_to_fault_free"],
        crc_warm_overhead_pct=ov["warm_overhead_pct"],
        crc_warm_under_5pct=ov["warm_under_5pct"])
    report["provenance"] = C.provenance("faults")
    dest = os.path.join(os.path.dirname(__file__), "..", "BENCH_faults.json")
    with open(os.path.abspath(dest), "w") as f:
        json.dump(report, f, indent=1)
    print(f"[bench_faults] wrote {os.path.abspath(dest)}")
    if fails:
        for msg in fails:
            print(f"[bench_faults] FAIL: {msg}", file=sys.stderr)
        raise SystemExit(1)
    if not ov["warm_under_5pct"]:
        print(f"[bench_faults] FAIL: warm checksum overhead "
              f"{ov['warm_overhead_pct']:.2f}% >= 5%", file=sys.stderr)
        raise SystemExit(1)
    return rows


# ---------------------------------------------------------------------------
# CI smoke
# ---------------------------------------------------------------------------


def quick_smoke() -> int:
    """CI smoke: the identical drill on tiny throwaway corpora (built in a
    tempdir — CI has no artifact cache).  Asserts zero worker deaths,
    100% completion-or-clean-rejection, quarantine + half-open recovery,
    CRC catches the corruption, and completed answers stay bit-identical
    to fault-free references."""
    import tempfile

    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.vamana import build_vamana
    from repro.data.vectors import make_clustered, make_queries

    t0 = time.perf_counter()
    n_sub, sub_n, d = 3, 800, 32
    base = make_clustered(n_sub * sub_n, d, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=8, iters=6)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    with tempfile.TemporaryDirectory() as td:
        paths, qpc = {}, {}
        for i in range(n_sub):
            sl = slice(i * sub_n, (i + 1) * sub_n)
            g = build_vamana(base[sl], R=12, L=24, seed=i)
            p = os.path.join(td, f"sub{i}")
            write_index(p, vectors=base[sl], graph=g, centroids=cents,
                        codes=codes[sl], metric="l2", mode="aisaq")
            paths[f"sub{i}"] = p
            qpc[f"sub{i}"] = make_queries(8, base[sl], seed=20 + i)
        stream = zipf_stream(n_sub, 120)
        drill = run_drill(paths, qpc, stream, k=5, L=24, w=W,
                          eio_rate=5e-3, corrupt_reads=6,
                          quarantine_after=2, cooldown_s=0.2,
                          recovery_timeout_s=15.0)
        fails = drill_failures(drill)
    wall = time.perf_counter() - t0
    if fails:
        for msg in fails:
            print(f"[bench_faults --quick] FAIL: {msg}", file=sys.stderr)
        return 1
    b = drill["buckets"]
    print(f"[bench_faults --quick] all fault-tolerance invariants hold "
          f"({wall:.1f}s): completed={b['completed']} "
          f"io_error={b['io_error']} rejected={b['unhealthy_rejected']} "
          f"quarantines={drill['sick_health']['quarantines']} "
          f"recoveries={drill['sick_health']['recoveries']} "
          f"retries={drill['cache_totals']['read_retries']} "
          f"crc_mismatches={drill['cache_totals']['crc_mismatches']}")
    return 0


if __name__ == "__main__":
    if "--quick" in sys.argv[1:]:
        sys.exit(quick_smoke())
    for name, val, extra in all_benchmarks():
        print(f"{name},{val:.3f},{extra}")
