"""Shared benchmark fixtures: one cached corpus + index family.

The Vamana graph is built ONCE and shared across all PQ sizes and both
placement modes (the paper does the same: same graph topology, different
placement/compression), so the full Fig-3/Fig-4/Table-2/3/4 suite needs a
single graph build.

Staleness protection: every cached artifact (corpus, graph, each index
dir) is stamped with a hash of the build parameters that produced it.  A
knob change (N, R, pq_m, relabel, index format, ...) therefore REBUILDS
the artifact instead of silently reusing a stale one — previously a
surviving ``bench_idx/`` would keep serving indices built under old
parameters.  ``benchmarks/run.py --rebuild`` force-clears the whole
artifact cache.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
IDX = os.path.join(ART, "bench_idx")

N, DIM, NQ = 20000, 96, 64
R, BUILD_L = 24, 40
PQ_MS = (12, 24, 48, 96)          # b_pq sweep for Fig. 4
DEFAULT_M = 48
PQ_ITERS = 8                      # codebook k-means iters (also stamped)

# bump when write_index's on-disk layout changes: stamps embed it, so a
# format change rebuilds every cached index
# v2: checksummed format (block_crc.npy sidecar + format_version in meta)
# v3: optional navigation-tier sidecar (nav_graph.npz + "nav" meta key)
FMT_VERSION = 3

# navigation-tier build knobs for the nav-twin indices (stamped, so a
# change here rebuilds the *_nav directories)
NAV_FRACTION = 0.02
NAV_DEGREE = 8
NAV_SEED = 0


# -- build-params stamping ---------------------------------------------------


def _params_hash(params: dict) -> str:
    return hashlib.sha1(
        json.dumps(params, sort_keys=True).encode()).hexdigest()[:16]


def _stamp_path(dirname: str, name: str) -> str:
    return os.path.join(dirname, name)


def _stamp_ok(dirname: str, name: str, params: dict) -> bool:
    """True iff `dirname` carries a stamp built from exactly `params`."""
    try:
        with open(_stamp_path(dirname, name)) as f:
            return json.load(f).get("hash") == _params_hash(params)
    except (OSError, ValueError):
        return False


def _write_stamp(dirname: str, name: str, params: dict):
    with open(_stamp_path(dirname, name), "w") as f:
        json.dump({"hash": _params_hash(params), "params": params}, f,
                  indent=1)


def force_rebuild():
    """Drop the whole cached corpus/graph/index family (run.py --rebuild)."""
    shutil.rmtree(IDX, ignore_errors=True)


def _corpus_params() -> dict:
    return dict(n=N, dim=DIM, nq=NQ, n_clusters=96, seed=0, qseed=1, gt_k=10)


def _graph_params() -> dict:
    return dict(corpus=_params_hash(_corpus_params()), R=R, build_L=BUILD_L,
                seed=0, two_pass=False)


# -- cached artifacts --------------------------------------------------------


def corpus():
    from repro.data.vectors import make_clustered, make_queries
    os.makedirs(IDX, exist_ok=True)
    fb, fq, fg = (os.path.join(IDX, x) for x in
                  ("base.npy", "queries.npy", "gt.npy"))
    params = _corpus_params()
    if os.path.exists(fb) and _stamp_ok(IDX, "corpus.stamp.json", params):
        return np.load(fb), np.load(fq), np.load(fg)
    base = make_clustered(N, DIM, n_clusters=96, seed=0)
    q = make_queries(NQ, base, seed=1)
    from repro.core import pq
    gt = pq.groundtruth(q, base, 10)
    np.save(fb, base), np.save(fq, q), np.save(fg, gt)
    _write_stamp(IDX, "corpus.stamp.json", params)
    return base, q, gt


def graph(base):
    from repro.core.vamana import build_vamana
    fg = os.path.join(IDX, "graph.npy")
    params = _graph_params()
    if os.path.exists(fg) and _stamp_ok(IDX, "graph.stamp.json", params):
        return np.load(fg)
    t0 = time.time()
    g = build_vamana(base, R=R, L=BUILD_L, seed=0, two_pass=False,
                     log_every=4000)
    print(f"[bench] vamana build {time.time()-t0:.0f}s")
    np.save(fg, g)
    _write_stamp(IDX, "graph.stamp.json", params)
    return g


def index_path(mode: str, m: int, relabel: bool = False,
               nav: bool = False) -> str:
    return os.path.join(IDX, f"{mode}_m{m}" + ("_rl" if relabel else "")
                        + ("_nav" if nav else ""))


def ensure_indices(ms=(DEFAULT_M,), modes=("aisaq", "diskann"),
                   shared_centroids_for=None, relabel=False, nav=False):
    """Build (cached) indices for each (mode, m). Returns paths dict.

    `relabel=True` builds the graph-locality-relabeled twins (same graph,
    same codes, permuted placement) into separate `*_rl` directories so
    the cold-path benchmark can compare the two layouts directly.
    `nav=True` additionally builds the navigation-tier sidecar into
    `*_nav` twins (same graph/codes/placement, plus the pivot graph) so
    nav-vs-medoid entry seeding is an apples-to-apples comparison.

    Each index dir is stamped with its build params (`build_params.json`);
    a stamp mismatch — knob change, format bump, upstream corpus/graph
    rebuild — removes and rebuilds that directory.
    """
    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    base, q, gt = corpus()
    g = graph(base)
    paths = {}
    for m in ms:
        cache = {}
        for mode in modes:
            p = index_path(mode, m, relabel, nav)
            paths[(mode, m)] = p
            params = dict(fmt=FMT_VERSION, graph=_params_hash(
                _graph_params()), mode=mode, m=m, relabel=bool(relabel),
                metric="l2", pq_iters=PQ_ITERS, pq_seed=m)
            if nav:
                params.update(nav_fraction=NAV_FRACTION,
                              nav_degree=NAV_DEGREE, nav_seed=NAV_SEED)
            if os.path.exists(os.path.join(p, "meta.json")) \
                    and _stamp_ok(p, "build_params.json", params):
                continue
            shutil.rmtree(p, ignore_errors=True)     # stale or absent
            if "cents" not in cache:
                cb = pq.train_codebooks(jax.random.PRNGKey(m), base, m=m,
                                        iters=PQ_ITERS)
                cache["cents"] = np.asarray(cb.centroids)
                cache["codes"] = np.asarray(pq.encode(cb, base))
            write_index(p, vectors=base, graph=g, centroids=cache["cents"],
                        codes=cache["codes"], metric="l2", mode=mode,
                        relabel=relabel, nav=nav, nav_fraction=NAV_FRACTION,
                        nav_degree=NAV_DEGREE, nav_seed=NAV_SEED)
            _write_stamp(p, "build_params.json", params)
    return paths


def ensure_subcorpora(n_sub=5, m=DEFAULT_M):
    """Sub-corpus indices sharing one PQ-centroid set (Table 4)."""
    import jax
    from repro.core import pq
    from repro.configs.base import IndexConfig
    from repro.core.build import build_index
    base, _, _ = corpus()
    cb = pq.train_codebooks(jax.random.PRNGKey(m), base, m=m, iters=PQ_ITERS)
    cents = np.asarray(cb.centroids)
    sub_n = 2000
    cfg = IndexConfig(name="sub", n_vectors=sub_n, dim=DIM, R=16, pq_m=m,
                      build_L=24)
    paths = {}
    for i in range(n_sub):
        p = os.path.join(IDX, f"sub_{i}")
        paths[f"sub{i}"] = p
        # derived from cfg, not re-typed: a knob edit must change the hash
        params = dict(fmt=FMT_VERSION, corpus=_params_hash(_corpus_params()),
                      m=m, sub_n=sub_n, i=i, R=cfg.R, build_L=cfg.build_L,
                      pq_iters=PQ_ITERS)
        if os.path.exists(os.path.join(p, "meta.json")) \
                and _stamp_ok(p, "build_params.json", params):
            continue
        shutil.rmtree(p, ignore_errors=True)
        build_index(p, base[i * sub_n:(i + 1) * sub_n], cfg,
                    mode="aisaq", shared_centroids=cents)
        _write_stamp(p, "build_params.json", params)
    return paths


def ensure_shard_indices(n_shards: int, m: int = DEFAULT_M,
                         total: int = N):
    """Per-shard AiSAQ indices over a contiguous split of (a prefix of)
    the cached corpus, for the multi-process cluster bench.

    Uses `core.shard_math.contiguous_shards` — the SAME assignment the
    device-mesh tier feeds `stack_shards` — and bakes each vector's
    GLOBAL id into the index via `write_index(labels=...)`, so cluster
    workers answer in global label space and the router merges without
    any offset arithmetic.  One PQ codebook (trained on the whole
    prefix) is shared by every shard, like the Table-4 sub-corpora.

    Returns (shard corpora list — one {"default": path} per shard —
    and the ShardAssignment)."""
    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    from repro.core.shard_math import contiguous_shards
    from repro.core.vamana import build_vamana
    base, _, _ = corpus()
    base = base[:total]
    asn = contiguous_shards(len(base), n_shards)
    cache = {}
    shards = []
    for s in range(n_shards):
        lo, hi = asn.bounds(s)
        p = os.path.join(IDX, f"shard_{n_shards}x{total}_{s}")
        shards.append({"default": p})
        params = dict(fmt=FMT_VERSION,
                      corpus=_params_hash(_corpus_params()),
                      m=m, total=total, n_shards=n_shards, s=s,
                      R=16, build_L=24, pq_iters=PQ_ITERS)
        if os.path.exists(os.path.join(p, "meta.json")) \
                and _stamp_ok(p, "build_params.json", params):
            continue
        shutil.rmtree(p, ignore_errors=True)
        if "cents" not in cache:
            cb = pq.train_codebooks(jax.random.PRNGKey(m), base, m=m,
                                    iters=PQ_ITERS)
            cache["cents"] = np.asarray(cb.centroids)
            cache["codes"] = np.asarray(pq.encode(cb, base))
        g = build_vamana(base[lo:hi], R=16, L=24, seed=s)
        write_index(p, vectors=base[lo:hi], graph=g,
                    centroids=cache["cents"], codes=cache["codes"][lo:hi],
                    metric="l2", mode="aisaq",
                    labels=np.arange(lo, hi, dtype=np.int64))
        _write_stamp(p, "build_params.json", params)
    return shards, asn


def rss_mb() -> float:
    import psutil
    return psutil.Process().memory_info().rss / 1e6


# -- report provenance -------------------------------------------------------


def provenance(schema: str) -> dict:
    """Uniform provenance header for every BENCH_*.json artifact.

    Regression tooling (`benchmarks/report.py`) and humans reading CI
    artifacts both need to know WHICH code on WHICH box produced a
    number before trusting a delta.  Never raises — a benchmark must
    not fail because git metadata is unavailable (e.g. a bare export).
    """
    import platform
    import subprocess
    from datetime import datetime, timezone
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=5,
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    return dict(
        schema=schema,
        git_commit=commit,
        host=dict(hostname=platform.node(),
                  machine=platform.machine(),
                  system=platform.system(),
                  python=platform.python_version(),
                  cpus=os.cpu_count()),
        timestamp=datetime.now(timezone.utc).isoformat(),
    )
