"""Shared benchmark fixtures: one cached corpus + index family.

The Vamana graph is built ONCE and shared across all PQ sizes and both
placement modes (the paper does the same: same graph topology, different
placement/compression), so the full Fig-3/Fig-4/Table-2/3/4 suite needs a
single graph build.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

ART = os.path.join(os.path.dirname(__file__), "artifacts")
IDX = os.path.join(ART, "bench_idx")

N, DIM, NQ = 20000, 96, 64
R, BUILD_L = 24, 40
PQ_MS = (12, 24, 48, 96)          # b_pq sweep for Fig. 4
DEFAULT_M = 48


def corpus():
    from repro.data.vectors import make_clustered, make_queries
    os.makedirs(IDX, exist_ok=True)
    fb, fq, fg = (os.path.join(IDX, x) for x in
                  ("base.npy", "queries.npy", "gt.npy"))
    if os.path.exists(fb):
        return np.load(fb), np.load(fq), np.load(fg)
    base = make_clustered(N, DIM, n_clusters=96, seed=0)
    q = make_queries(NQ, base, seed=1)
    from repro.core import pq
    gt = pq.groundtruth(q, base, 10)
    np.save(fb, base), np.save(fq, q), np.save(fg, gt)
    return base, q, gt


def graph(base):
    from repro.core.vamana import build_vamana
    fg = os.path.join(IDX, "graph.npy")
    if os.path.exists(fg):
        return np.load(fg)
    t0 = time.time()
    g = build_vamana(base, R=R, L=BUILD_L, seed=0, two_pass=False,
                     log_every=4000)
    print(f"[bench] vamana build {time.time()-t0:.0f}s")
    np.save(fg, g)
    return g


def index_path(mode: str, m: int, relabel: bool = False) -> str:
    return os.path.join(IDX, f"{mode}_m{m}" + ("_rl" if relabel else ""))


def ensure_indices(ms=(DEFAULT_M,), modes=("aisaq", "diskann"),
                   shared_centroids_for=None, relabel=False):
    """Build (cached) indices for each (mode, m). Returns paths dict.

    `relabel=True` builds the graph-locality-relabeled twins (same graph,
    same codes, permuted placement) into separate `*_rl` directories so
    the cold-path benchmark can compare the two layouts directly.
    """
    import jax
    from repro.core import pq
    from repro.core.index_io import write_index
    base, q, gt = corpus()
    g = graph(base)
    paths = {}
    for m in ms:
        cache = {}
        for mode in modes:
            p = index_path(mode, m, relabel)
            paths[(mode, m)] = p
            if os.path.exists(os.path.join(p, "meta.json")):
                continue
            if "cents" not in cache:
                cb = pq.train_codebooks(jax.random.PRNGKey(m), base, m=m,
                                        iters=8)
                cache["cents"] = np.asarray(cb.centroids)
                cache["codes"] = np.asarray(pq.encode(cb, base))
            write_index(p, vectors=base, graph=g, centroids=cache["cents"],
                        codes=cache["codes"], metric="l2", mode=mode,
                        relabel=relabel)
    return paths


def ensure_subcorpora(n_sub=5, m=DEFAULT_M):
    """Sub-corpus indices sharing one PQ-centroid set (Table 4)."""
    import jax
    from repro.core import pq
    from repro.configs.base import IndexConfig
    from repro.core.build import build_index
    base, _, _ = corpus()
    cb = pq.train_codebooks(jax.random.PRNGKey(m), base, m=m, iters=8)
    cents = np.asarray(cb.centroids)
    sub_n = 2000
    cfg = IndexConfig(name="sub", n_vectors=sub_n, dim=DIM, R=16, pq_m=m,
                      build_L=24)
    paths = {}
    for i in range(n_sub):
        p = os.path.join(IDX, f"sub_{i}")
        paths[f"sub{i}"] = p
        if not os.path.exists(os.path.join(p, "meta.json")):
            build_index(p, base[i * sub_n:(i + 1) * sub_n], cfg,
                        mode="aisaq", shared_centroids=cents)
    return paths


def rss_mb() -> float:
    import psutil
    return psutil.Process().memory_info().rss / 1e6
