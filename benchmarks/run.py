# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["paper", "device", "search",
                                       "serving"],
                    default=None)
    ap.add_argument("--rebuild", action="store_true",
                    help="drop the cached corpus/graph/index artifacts and "
                         "rebuild from scratch (stamps normally rebuild "
                         "only on a build-params mismatch)")
    args = ap.parse_args(argv)
    if args.rebuild:
        from benchmarks import common
        common.force_rebuild()
    rows = []
    if args.only in (None, "paper"):
        from benchmarks.bench_paper import all_benchmarks as paper
        rows += paper()
    if args.only in (None, "device"):
        from benchmarks.bench_device import all_benchmarks as device
        rows += device()
    if args.only in (None, "search"):
        from benchmarks.bench_search import all_benchmarks as search
        rows += search()
    if args.only in (None, "serving"):
        from benchmarks.bench_serving import all_benchmarks as serving
        rows += serving()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
