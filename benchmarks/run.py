# One function per paper table. Print ``name,us_per_call,derived`` CSV.
import argparse
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["paper", "device", "search",
                                       "serving"],
                    default=None)
    args = ap.parse_args(argv)
    rows = []
    if args.only in (None, "paper"):
        from benchmarks.bench_paper import all_benchmarks as paper
        rows += paper()
    if args.only in (None, "device"):
        from benchmarks.bench_device import all_benchmarks as device
        rows += device()
    if args.only in (None, "search"):
        from benchmarks.bench_search import all_benchmarks as search
        rows += search()
    if args.only in (None, "serving"):
        from benchmarks.bench_serving import all_benchmarks as serving
        rows += serving()
    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.2f},{derived}")


if __name__ == "__main__":
    main()
