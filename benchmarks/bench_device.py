"""Device-backend benchmarks (ours, beyond-paper): batched device beam
search, bulk ADC scoring, and the AiSAQ-mode recsys retrieval path."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C


def _timeit(fn, *args, iters=3):
    fn(*args)                                    # compile + warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def device_beam_search():
    from repro.core import pq
    from repro.core.device_index import beam_search_device, from_arrays
    from repro.core.index_io import recall_at
    base, q, gt = C.corpus()
    g = C.graph(base)
    cb = pq.train_codebooks(jax.random.PRNGKey(C.DEFAULT_M), base,
                            m=C.DEFAULT_M, iters=8)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    rows = []
    for mode in ("aisaq", "diskann"):
        idx, lay = from_arrays(base, g, cents, codes, mode=mode)
        fn = lambda qq: beam_search_device(idx, qq, k=10, L=40, layout=lay,
                                           metric="l2")[0]
        qd = jnp.asarray(q)
        dt = _timeit(fn, qd)
        ids = np.asarray(fn(qd))
        r1 = recall_at(ids, gt, 1)
        rows.append((f"device_beam_{mode}", dt / q.shape[0] * 1e6,
                     f"recall1={r1:.3f}_batch={q.shape[0]}"))
    return rows


def bulk_adc_scoring():
    """retrieval_cand regime: score all N codes against one query."""
    from repro.core import pq
    from repro.kernels import ops
    base, q, _ = C.corpus()
    cb = pq.train_codebooks(jax.random.PRNGKey(1), base, m=16, iters=6)
    codes = jnp.asarray(pq.encode(cb, base))
    lut = ops.build_lut(jnp.asarray(q[:8]), cb.centroids, metric="l2")
    fn = jax.jit(lambda l, c: ops.adc(l, c))
    dt = _timeit(fn, lut, codes)
    rate = 8 * base.shape[0] / dt / 1e6
    return [("bulk_adc", dt * 1e6, f"Mscores_per_s={rate:.1f}")]


def recsys_pq_retrieval():
    """AiSAQ-mode candidate scoring for sasrec (exact vs PQ+rerank)."""
    from repro.configs import get_arch
    from repro.core import pq
    from repro.models import recsys as R
    arch = get_arch("sasrec")
    cfg = arch.model.scaled(vocab_sizes=(20000,), seq_len=16)
    p = R.init_recsys(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    batch = {"seq": jnp.asarray(rng.integers(0, 20000, (1, 16)), jnp.int32),
             "cand_ids": jnp.arange(20000, dtype=jnp.int32)}
    cand = np.asarray(jnp.take(p["tables"][0], batch["cand_ids"], axis=0)
                      @ p["item_proj"])
    cb = pq.train_codebooks(jax.random.PRNGKey(1), cand, m=10, iters=6)
    codes = jnp.asarray(pq.encode(cb, cand))
    f_exact = jax.jit(lambda b: R.retrieval_topk(p, b, cfg, k=100)[0])
    f_pq = jax.jit(lambda b: R.retrieval_topk_pq(p, b, cfg, codes,
                                                 cb.centroids, k=100)[0])
    t_e = _timeit(f_exact, batch)
    t_p = _timeit(f_pq, batch)
    ids_e = set(np.asarray(f_exact(batch))[0].tolist())
    ids_p = set(np.asarray(f_pq(batch))[0].tolist())
    ov = len(ids_e & ids_p) / 100
    return [("retrieval_exact", t_e * 1e6, "per_query"),
            ("retrieval_pq_rerank", t_p * 1e6,
             f"overlap_top100={ov:.2f}")]


def kernel_microbench():
    """Interpret-mode kernels vs refs (semantics only; CPU wall time is NOT
    TPU-indicative — roofline covers perf)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    lut = jnp.asarray(rng.random((4, 32, 256)).astype(np.float32))
    codes = jnp.asarray(rng.integers(0, 256, (4096, 32)).astype(np.uint8))
    t_ref = _timeit(lambda: ops.adc(lut, codes, backend="ref"))
    return [("kernel_adc_ref_path", t_ref * 1e6, "semantic_oracle")]


def all_benchmarks():
    rows = []
    for fn in (device_beam_search, bulk_adc_scoring, recsys_pq_retrieval,
               kernel_microbench):
        t0 = time.time()
        rows += fn()
        print(f"[bench] {fn.__name__} done in {time.time()-t0:.0f}s",
              flush=True)
    return rows
