"""Quickstart: build an AiSAQ index, search it, compare with DiskANN mode.

    PYTHONPATH=src python examples/quickstart.py

Demonstrates the paper's three headline claims at laptop scale:
  1. identical recall to DiskANN (same graph topology),
  2. ~N-independent RAM residency (only centroids + entry-point codes),
  3. near-zero index load time.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.index_io import HostIndex, recall_at
from repro.data.vectors import make_clustered, make_queries


def main():
    n, d = 5000, 64
    print(f"== corpus: {n} x {d} clustered vectors ==")
    base = make_clustered(n, d, seed=0)
    queries = make_queries(32, base)
    gt = pq.groundtruth(queries, base, 10)

    cfg = IndexConfig(name="quickstart", n_vectors=n, dim=d, R=24, pq_m=16,
                      build_L=48)
    root = tempfile.mkdtemp(prefix="aisaq_quickstart_")
    results = {}
    for mode in ("aisaq", "diskann"):
        path = os.path.join(root, mode)
        t0 = time.time()
        # nav=True packs the in-RAM navigation tier (docs/navigation.md)
        # alongside the AiSAQ index; the DiskANN twin stays plain
        meta = build_index(path, base, cfg, mode=mode, seed=0,
                           nav=(mode == "aisaq"))
        print(f"\n[{mode}] built in {time.time()-t0:.1f}s  "
              f"chunk={meta['chunk_bytes']}B  io/hop={meta['io_bytes']}B")
        idx = HostIndex.load(path)
        print(f"[{mode}] load time     : {idx.load_time_s*1e3:.2f} ms")
        print(f"[{mode}] resident bytes: {idx.resident_bytes()/1e3:.1f} KB")
        # entry="medoid" pins the classic fixed-seed traversal so the
        # AiSAQ/DiskANN comparison stays apples-to-apples (the nav demo
        # below opts in explicitly)
        ids, stats = idx.search_batch(queries, 10, L=48, entry="medoid")
        results[mode] = ids
        lat = np.mean([s.latency_s for s in stats]) * 1e3
        print(f"[{mode}] recall@1={recall_at(ids, gt, 1):.3f} "
              f"recall@10={recall_at(ids, gt, 10):.3f} "
              f"mean latency={lat:.2f} ms "
              f"ios/query={np.mean([s.ios for s in stats]):.0f} "
              f"hops/query={np.median([s.hops for s in stats]):.0f} "
              f"(converged by hop "
              f"{np.median([s.convergence_hop for s in stats]):.0f})")
        # the pipelined traversal engine (core.traversal): prefetch>0
        # turns on the two-hop in-flight path — identical ids, reads off
        # the critical path; overlap is visible in the lead query's stats
        idx.cache.clear()
        ids_p, stats_p = idx.search_batch(queries, 10, L=48, prefetch=4,
                                          entry="medoid")
        assert np.array_equal(ids, ids_p)
        print(f"[{mode}] pipelined: blocked wait "
              f"{stats_p[0].blocked_wait_s*1e3:.2f} ms vs compute "
              f"{stats_p[0].compute_s*1e3:.2f} ms (whole batch, "
              f"results identical)")
        if idx.nav is not None:
            # the navigation tier: an in-RAM beam over ~2% pivot nodes
            # replaces the fixed medoid seed with per-query entry
            # vertices — fewer on-disk hops, zero extra storage I/O
            ids_n, st_n = idx.search_batch(queries, 10, L=48, entry="nav")
            print(f"[{mode}] nav entry: hops/query="
                  f"{np.median([s.hops for s in st_n]):.0f} "
                  f"(converged by hop "
                  f"{np.median([s.convergence_hop for s in st_n]):.0f}) "
                  f"recall@10={recall_at(ids_n, gt, 10):.3f}  "
                  f"[nav tier: {idx.nav.resident_nbytes()/1e3:.1f} KB, "
                  f"{idx.nav.params['pivots']} pivots]")
        idx.close()

    same = np.array_equal(results["aisaq"], results["diskann"])
    print(f"\nAiSAQ results identical to DiskANN (same topology): {same}")


if __name__ == "__main__":
    main()
