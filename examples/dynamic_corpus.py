"""Dynamic RAG corpus: live insertion + deletion + filtered retrieval.

    PYTHONPATH=src python examples/dynamic_corpus.py

The paper's conclusion says AiSAQ's near-zero load time "will enable LLMs
with RAG to employ more simple index addition or filter search algorithms" —
this example exercises exactly that: documents stream into a live index
(in-place chunk appends + reverse-edge patches), stale documents are
tombstoned, and queries filter by a freshness predicate.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs.base import IndexConfig
from repro.core.build import build_index
from repro.core.dynamic import DynamicHostIndex
from repro.data.vectors import make_clustered, make_queries


def main():
    d = 48
    base = make_clustered(2000, d, seed=0)
    cfg = IndexConfig(name="dyn", n_vectors=1500, dim=d, R=16, pq_m=12,
                      build_L=32)
    root = tempfile.mkdtemp(prefix="dyn_")
    path = os.path.join(root, "corpus")
    print("== building initial 1500-doc index ==")
    build_index(path, base[:1500], cfg, mode="aisaq", seed=0)
    idx = DynamicHostIndex.load(path)

    print("== streaming 100 new documents into the live index ==")
    t0 = time.perf_counter()
    for i in range(100):
        idx.insert(base[1500 + i])
    dt = (time.perf_counter() - t0) / 100
    print(f"   mean insert latency: {dt*1e3:.1f} ms/doc "
          f"(search + <=R reverse-edge chunk patches)")

    q = base[1550].astype(np.float32)
    ids, _ = idx.search(q, 5, L=48)
    print(f"   freshly-inserted doc findable: "
          f"{1550 in set(int(i) for i in ids)} (top-5 {ids.tolist()})")

    print("== tombstoning 10 stale docs ==")
    for v in range(1500, 1510):
        idx.delete(v)
    ids, _ = idx.search(base[1505].astype(np.float32), 5, L=48)
    print(f"   deleted docs excluded: "
          f"{not (set(range(1500, 1510)) & set(int(i) for i in ids))}")

    print("== filtered retrieval (only even-id 'fresh' docs) ==")
    ids, _ = idx.search(q, 5, L=48, predicate=lambda i: i % 2 == 0)
    print(f"   filtered top-5: {ids.tolist()} (all even: "
          f"{all(int(i) % 2 == 0 for i in ids)})")

    idx.flush()
    idx.close()
    print("flushed: appended codes + tombstones persist across reloads")


if __name__ == "__main__":
    main()
