"""Multi-device sharded AiSAQ search — the paper's Fig. 5 multi-server
system on a local 8-device mesh (2 data x 4 model).

    PYTHONPATH=src python examples/distributed_search.py

Each of the 4 `model`-axis devices owns a dataset shard with its own
sub-index (exactly the paper's per-server layout); queries split over the
`data` axis; results merge via all-gather + global top-k.
"""
import os
import sys

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pq
from repro.core.chunk_layout import ChunkLayout
from repro.core.index_io import recall_at
from repro.core.sharded_search import (input_sharding, sharded_search_fn,
                                       stack_shards)
from repro.core.vamana import build_sharded
from repro.data.vectors import make_clustered, make_queries
from repro.launch.mesh import make_test_mesh


def main():
    n, d, m, R = 4000, 48, 12, 20
    print(f"== {n} vectors over 4 index shards, 8 virtual devices ==")
    base = make_clustered(n, d, seed=0)
    queries = make_queries(16, base)
    gt = pq.groundtruth(queries, base, 10)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), base, m=m)
    cents, codes = np.asarray(cb.centroids), np.asarray(pq.encode(cb, base))
    lay = ChunkLayout("aisaq", d, "float32", R, m)
    print("building 4 per-shard Vamana sub-indices ...")
    shards = build_sharded(base, 4, R=R, L=32, seed=0)
    arrays = stack_shards(shards, cents, codes, lay)

    mesh = make_test_mesh((2, 4), ("data", "model"))
    search = jax.jit(sharded_search_fn(
        mesh, k=10, L=48, w=4, max_hops=64, layout=lay, metric="l2",
        backend="ref"))
    ash, qsh = input_sharding(mesh)
    arrays = jax.tree.map(jax.device_put, arrays, ash)
    qdev = jax.device_put(jnp.asarray(queries), qsh)

    ids, dists = search(arrays, qdev)          # compile
    t0 = time.perf_counter()
    ids, dists = jax.block_until_ready(search(arrays, qdev))
    dt = time.perf_counter() - t0
    ids = np.asarray(ids)
    print(f"recall@1 = {recall_at(ids, gt, 1):.3f}   "
          f"recall@10 = {recall_at(ids, gt, 10):.3f}")
    print(f"batch latency {dt*1e3:.1f} ms for {queries.shape[0]} queries "
          f"across 4 shards x 2 query groups")
    print("per-shard fast-tier residency is (R + n_ep) codes + centroids — "
          "independent of shard size (the paper's scale-out claim)")


if __name__ == "__main__":
    main()
