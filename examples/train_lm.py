"""End-to-end driver (deliverable b): train a ~100M-param qwen3-family LM
for a few hundred steps with checkpointing + a mid-run simulated failure
and automatic restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200]

Uses the same launcher the dry-run validates at 512 chips, on a 1-device
CPU mesh with a ~100M-parameter reduction of qwen3-1.7b.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro.configs import get_arch
from repro.distributed.fault_tolerance import run_with_restarts
from repro.launch import train as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a worker failure at this step")
    args = ap.parse_args()

    # ~100M params: 8L x d512 x ff2048, vocab 32k
    def scale_100m(cfg):
        return cfg.scaled(n_layers=8, d_model=512, n_heads=8, n_kv_heads=4,
                          head_dim=64, d_ff=2048, vocab_size=32768,
                          moe=None, dtype="float32")

    T.tiny_lm = scale_100m  # reuse launcher plumbing with the 100M scale
    arch = get_arch("qwen3-1.7b")
    print(f"training {scale_100m(arch.model).n_params()/1e6:.0f}M-param LM "
          f"for {args.steps} steps")
    ckpt = tempfile.mkdtemp(prefix="lm_ckpt_")

    injected = []

    def segment(resume):
        fail = args.fail_at if (args.fail_at and not injected) else None
        if fail:
            injected.append(True)
        return T.train_loop("qwen3-1.7b", "train_4k", steps=args.steps,
                            ckpt_dir=ckpt, ckpt_every=25,
                            fail_at_step=fail)["final_step"]

    final = run_with_restarts(segment, max_restarts=2,
                              on_restart=lambda n: print(
                                  f"[launcher] restart #{n} from checkpoint"))
    print(f"done at step {final}; checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
