"""RAG-style multi-corpus retrieval (paper §2.2 / Table 4) served by the
multi-tenant `RetrievalService`: a warm-index pool keeps every corpus open
under one DRAM budget (shared PQ centroids charged once), per-corpus queues
serve tenants concurrently, and the exact rerank tier rescores candidates
with the full-precision vectors already sitting in the traversal chunks.

    PYTHONPATH=src python examples/rag_retrieval.py

A simulated LLM chain issues retrievals against three different corpora
(news / docs / code) that share one embedding space, so their AiSAQ indices
share PQ centroids — co-residency costs one centroid table + ~KBs per
corpus.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.data.vectors import make_clustered, make_queries
from repro.serving import RetrievalService, WarmIndexPool


def main():
    d, n_per = 64, 3000
    print("== building 3 corpora sharing one vector space ==")
    everything = make_clustered(3 * n_per, d, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), everything, m=16)
    cents = np.asarray(cb.centroids)
    root = tempfile.mkdtemp(prefix="rag_")
    cfg = IndexConfig(name="rag", n_vectors=n_per, dim=d, R=20, pq_m=16,
                      build_L=32)
    corpora = {}
    for i, name in enumerate(("news", "docs", "code")):
        p = os.path.join(root, name)
        build_index(p, everything[i * n_per:(i + 1) * n_per], cfg,
                    mode="aisaq", shared_centroids=cents)
        corpora[name] = p
        print(f"  built {name}")

    # budget generous enough for all three corpora: every index stays warm
    pool = WarmIndexPool(corpora, budget_bytes=64 << 20,
                         cache_bytes=2 << 20)
    svc = RetrievalService(pool, num_workers=2, max_wait_ms=1.0, L=32,
                           rerank=20)        # exact rerank tier on

    print("\n== simulated RAG chain: 12 retrievals across corpora ==")
    chain = ["news", "docs", "docs", "code", "news", "code"] * 2
    queries = make_queries(len(chain), everything, seed=3)
    for step, corpus in enumerate(chain):
        r = svc.submit_wait(queries[step], corpus=corpus, k=5)
        print(f"  step {step:2d} [{corpus:4s}] top-5 ids {r.result.tolist()} "
              f"latency {r.latency_s*1e3:.2f} ms")

    st = svc.stats()
    ps = st["pool"]
    print(f"\nper-corpus serving stats:")
    for name, c in st["corpora"].items():
        print(f"  {name:4s} completed={c['completed']} "
              f"switches={c['switches']} p50={c.get('p50_ms', 0):.2f}ms")
    print(f"pool: open={ps['open']}/{ps['registered']} warm, "
          f"hits={ps['hits']} misses={ps['misses']} "
          f"evictions={ps['evictions']}")
    print(f"shared-centroid dedup: {ps['centroid_shares']} corpora reuse "
          f"one {ps['centroid_bytes']/1e3:.1f} KB table")
    print(f"DRAM for ALL {ps['open']} warm corpora: "
          f"{ps['used_bytes']/1e6:.2f} MB (vs one-at-a-time switching — "
          "that's the point)")
    svc.stop()
    pool.close()


if __name__ == "__main__":
    main()
