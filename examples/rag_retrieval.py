"""RAG-style multi-corpus retrieval with millisecond index switching
(paper §2.2 / Table 4) served through the batching engine with hedging.

    PYTHONPATH=src python examples/rag_retrieval.py

A simulated LLM chain issues retrievals against three different corpora
(news / docs / code) that share one embedding space, so their AiSAQ indices
share PQ centroids — switching costs only the entry-point metadata load.
"""
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.configs.base import IndexConfig
from repro.core import pq
from repro.core.build import build_index
from repro.core.index_switch import IndexManager
from repro.data.vectors import make_clustered, make_queries
from repro.serving.engine import ServingEngine


def main():
    d, n_per = 64, 3000
    print("== building 3 corpora sharing one vector space ==")
    everything = make_clustered(3 * n_per, d, seed=0)
    cb = pq.train_codebooks(jax.random.PRNGKey(0), everything, m=16)
    cents = np.asarray(cb.centroids)
    root = tempfile.mkdtemp(prefix="rag_")
    cfg = IndexConfig(name="rag", n_vectors=n_per, dim=d, R=20, pq_m=16,
                      build_L=32)
    corpora = {}
    for i, name in enumerate(("news", "docs", "code")):
        p = os.path.join(root, name)
        build_index(p, everything[i * n_per:(i + 1) * n_per], cfg,
                    mode="aisaq", shared_centroids=cents)
        corpora[name] = p
        print(f"  built {name}")

    mgr = IndexManager(corpora)

    def search(queries, k):
        ids, _ = mgr.search_batch(queries, k, L=32)
        return ids

    eng = ServingEngine({c: search for c in corpora}, switch_fn=mgr.switch,
                        max_wait_ms=1.0)
    print("\n== simulated RAG chain: 12 retrievals across corpora ==")
    chain = ["news", "docs", "docs", "code", "news", "code"] * 2
    queries = make_queries(len(chain), everything, seed=3)
    for step, corpus in enumerate(chain):
        r = eng.submit_wait(queries[step], corpus=corpus, k=5)
        print(f"  step {step:2d} [{corpus:4s}] top-5 ids {r.result.tolist()} "
              f"latency {r.latency_s*1e3:.2f} ms")
    print(f"\nindex switches: {len(eng.switch_times)}; switch times (ms): "
          f"{[f'{t*1e3:.2f}' for t in eng.switch_times]}")
    print(f"serving percentiles: {eng.latency_percentiles()}")
    print(f"resident bytes while serving 3 corpora: "
          f"{mgr.resident_bytes()/1e3:.1f} KB (one corpus at a time — "
          "that's the point)")
    eng.stop()
    mgr.close()


if __name__ == "__main__":
    main()
